//! [`WireServer`]: the blocking TCP front-end that puts a
//! [`SimServer`](crate::serve::SimServer) on the network.
//!
//! One accept thread hands each connection to a dedicated **reader
//! thread** that parses frames off the socket and a **writer thread**
//! that drains a bounded outbox onto it; every granted lease gets a
//! **session pump** thread that owns the in-process
//! [`Session`](crate::serve::Session) and turns `Submit` frames into
//! `submit_at → wait → Step` cycles. A single socket therefore
//! multiplexes any number of sessions: the reader routes `Submit` /
//! `Detach` frames to pumps by wire session id, and all server→client
//! frames (grants, step views, errors) funnel through the one outbox so
//! the socket is written from exactly one thread.
//!
//! **Backpressure / slow readers.** The outbox is a bounded channel
//! ([`WireConfig::outbox_frames`]). A client that stops draining its
//! socket eventually fills it; the next frame *disconnects* the
//! connection instead of blocking a shard's pump behind one slow peer
//! (`dropped_slow` in the [`ConnStats`] row) — after a best-effort
//! `ERR_SLOW_READER` farewell written straight onto the socket, so the
//! policy disconnect is never silent. Inbound is bounded too: each
//! session's submit queue holds at most [`WireConfig::inbox_submits`];
//! a peer flooding submits faster than the shard steps has the excess
//! submit *shed* with an `ERR_RETRY_AFTER` frame (carrying a
//! `retry_after_ms=` hint) while the connection and the lease survive.
//! Disconnect — slow, hostile, or crashed — detaches the connection's
//! sessions, so their slots fall back to the auto-reset filler and
//! co-tenants keep stepping.
//!
//! **Resume (DESIGN.md §0.12).** Every grant carries an opaque resume
//! token. With [`WireConfig::park_ttl_ticks`] set, an env session whose
//! connection dies is *parked* instead of detached: the lease is held,
//! the shard (if this session is its sole tenant) freezes, and a client
//! that reconnects within the TTL sends `RESUME{session, token,
//! delivered}` to reclaim it. The server answers `RESUMED{applied}`
//! and replays the one step the client missed (if any), making the
//! delivered observation stream bitwise-identical to an undisturbed
//! run. Expired parks release their leases via the accept loop's
//! reaper.
//!
//! **Hostile input.** Frame validation happens before allocation (see
//! [`frame`](super::frame)); a malformed frame earns a best-effort error
//! frame and a closed connection, counted in `bad_frames`. Slot indices
//! inside well-formed `Submit` frames are untrusted too — the coalescer
//! bounds-checks them (shard `bad_submits` stat) rather than indexing
//! blindly while holding the shard mutex. One caveat is inherited from
//! the in-process layer: on a `StragglerPolicy::Wait` shard, a tenant
//! that leases slots and then never submits stalls its co-tenants —
//! serve open traffic with a `Deadline` policy, which also guarantees
//! pump threads cannot block forever on a vanished peer's last step.

use std::collections::HashMap;
use std::io::Read;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::obs::{
    Counter, EventLog, Gauge, Heartbeat, Histogram, Registry, TraceSink, Trigger,
    SNAPSHOT_VERSION, WIRE_PID,
};
use crate::serve::fault::Injector;
use crate::serve::server::LeaseDecline;
use crate::serve::session::{Session, SessionView};
use crate::serve::tenant::session::{ActionMode, TenantControl, TenantSession, TrajStep};
use crate::serve::SimServer;
use crate::util::json::Json;

use super::frame::{
    self, with_retry_after, Frame, ReadError, StepRef, ERR_LEASE, ERR_PROTOCOL, ERR_RETRY_AFTER,
    ERR_SESSION, ERR_SHARD, ERR_SHARD_DOWN, ERR_SLOW_READER, ERR_SUBMIT,
};

/// Wire front-end knobs.
#[derive(Clone)]
pub struct WireConfig {
    /// Server→client frames buffered per connection before the
    /// slow-reader disconnect policy fires.
    pub outbox_frames: usize,
    /// Client→server submits buffered per *session* before the flood
    /// policy sheds the excess submit with an `ERR_RETRY_AFTER` frame
    /// (the connection survives). A well-behaved client pipelines one
    /// or two submits; without this bound a peer writing submits faster
    /// than the shard steps would grow server memory at line rate.
    pub inbox_submits: usize,
    /// Reap a connection after this many idle ticks (units of
    /// [`TICK`](crate::serve::TICK), i.e. milliseconds) with no frame
    /// read from *or* written to the peer. A reaped connection is closed
    /// like any other disconnect — its leases release, its slots fall
    /// back to the auto-reset filler — and its [`ConnStats`] row is
    /// flagged `reaped`. `None` (the default) never reaps: a legitimate
    /// client may idle-hold a lease indefinitely.
    pub idle_timeout_ticks: Option<u64>,
    /// Park env sessions of a dead connection for this many ticks
    /// (milliseconds) awaiting a `RESUME`, instead of detaching them
    /// immediately. `None` (the default) keeps the historical
    /// detach-on-disconnect behavior. (`bps serve --park-ttl`.)
    pub park_ttl_ticks: Option<u64>,
    /// Fault-injection plane for chaos drills (`bps serve --fault`,
    /// DESIGN.md §0.12): connection drops, write delays, and payload
    /// corruption are applied in [`writer_loop`]; shard panics fire in
    /// the shard drivers via `SimServer::arm_faults`.
    pub fault: Option<Arc<Injector>>,
}

impl Default for WireConfig {
    fn default() -> WireConfig {
        WireConfig {
            outbox_frames: 256,
            inbox_submits: 64,
            idle_timeout_ticks: None,
            park_ttl_ticks: None,
            fault: None,
        }
    }
}

/// Point-in-time counters for one connection (alive or closed); closed
/// rows are kept for post-mortems up to a retention cap, then pruned
/// oldest-first ([`WireServer::conn_stats`]).
#[derive(Clone, Debug)]
pub struct ConnStats {
    pub id: u64,
    pub peer: String,
    /// Sessions currently leased through this connection.
    pub sessions_open: u64,
    /// Sessions ever granted on this connection, cumulative.
    pub sessions_opened: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Frame-grammar violations received from this peer.
    pub bad_frames: u64,
    /// True when the slow-reader policy disconnected the peer.
    pub dropped_slow: bool,
    /// True when the idle-timeout reaper closed the connection
    /// ([`WireConfig::idle_timeout_ticks`]).
    pub reaped: bool,
    pub closed: bool,
}

/// Server-wide wire counters on the [`SimServer`]'s metrics registry
/// (`wire.*` metric family). Per-connection rows keep their own exact
/// atomics in [`ConnShared`] — these cells aggregate across connections
/// (including ones whose closed rows were pruned), so a scrape sees the
/// transport's lifetime totals. Cheap to clone: every cell is an `Arc`.
#[derive(Clone)]
struct WireObs {
    conns_accepted: Counter,
    conns_open: Gauge,
    sessions_open: Gauge,
    sessions_opened: Counter,
    frames_in: Counter,
    bytes_in: Counter,
    frames_out: Counter,
    bytes_out: Counter,
    bad_frames: Counter,
    errors_out: Counter,
    dropped_slow: Counter,
    reaped: Counter,
    /// Fault-tolerance plane (DESIGN.md §0.12): parked-session
    /// lifecycle, resume outcomes, and flood sheds.
    park_parked: Counter,
    park_expired: Counter,
    park_open: Gauge,
    resume_ok: Counter,
    resume_fail: Counter,
    shed_flood: Counter,
    /// Latency-attribution phases owned by the wire layer: serializing a
    /// step/traj view into frame bytes, and flushing those bytes onto
    /// the socket (`serve.session.phase_us{phase=...}`).
    encode_us: Histogram,
    flush_us: Histogram,
}

impl WireObs {
    fn new(reg: &Registry) -> WireObs {
        let no_labels: &[(&str, &str)] = &[];
        WireObs {
            conns_accepted: reg.counter("wire.conns_accepted", no_labels),
            conns_open: reg.gauge("wire.conns_open", no_labels),
            sessions_open: reg.gauge("wire.sessions_open", no_labels),
            sessions_opened: reg.counter("wire.sessions_opened", no_labels),
            frames_in: reg.counter("wire.frames_in", no_labels),
            bytes_in: reg.counter("wire.bytes_in", no_labels),
            frames_out: reg.counter("wire.frames_out", no_labels),
            bytes_out: reg.counter("wire.bytes_out", no_labels),
            bad_frames: reg.counter("wire.bad_frames", no_labels),
            errors_out: reg.counter("wire.errors_out", no_labels),
            dropped_slow: reg.counter("wire.dropped_slow", no_labels),
            reaped: reg.counter("wire.reaped", no_labels),
            park_parked: reg.counter("serve.park.parked", no_labels),
            park_expired: reg.counter("serve.park.expired", no_labels),
            park_open: reg.gauge("serve.park.open", no_labels),
            resume_ok: reg.counter("serve.resume.ok", no_labels),
            resume_fail: reg.counter("serve.resume.fail", no_labels),
            shed_flood: reg.counter("serve.shed.flood", no_labels),
            encode_us: reg.histogram("serve.session.phase_us", &[("phase", "wire_encode")]),
            flush_us: reg.histogram("serve.session.phase_us", &[("phase", "wire_flush")]),
        }
    }
}

/// Shared per-connection state (stats + the shutdown handle).
struct ConnShared {
    id: u64,
    peer: String,
    /// A clone of the connection socket kept for `close`: shutting it
    /// down unblocks the reader and writer wherever they are. Taken
    /// (freeing the fd) on close — stats rows outlive the connection,
    /// and must not pin a descriptor each.
    stream: Mutex<Option<TcpStream>>,
    frames_in: AtomicU64,
    bytes_in: AtomicU64,
    frames_out: AtomicU64,
    bytes_out: AtomicU64,
    bad_frames: AtomicU64,
    sessions_open: AtomicU64,
    sessions_opened: AtomicU64,
    dropped_slow: AtomicBool,
    reaped: AtomicBool,
    closed: AtomicBool,
    /// Server-wide epoch the activity clock counts from.
    epoch: Instant,
    /// Milliseconds-since-`epoch` of the last frame read from or
    /// written to this peer — the idle reaper's clock. Outbound counts
    /// too: a streaming policy tenant legitimately sends nothing after
    /// its goal, but the `TRAJ` frames it drains prove it alive.
    last_activity_ms: AtomicU64,
    /// Server-wide aggregate cells this connection also feeds.
    obs: WireObs,
    /// Lifecycle event sink (shared with the backing [`SimServer`]).
    events: Arc<EventLog>,
    /// Megaframe trace sink, for the wire encode/flush spans.
    trace: Arc<TraceSink>,
    /// Connection-level fault injector ([`WireConfig::fault`]), applied
    /// by the writer thread.
    fault: Option<Arc<Injector>>,
}

impl ConnShared {
    fn touch(&self) {
        self.last_activity_ms
            .store(self.epoch.elapsed().as_millis() as u64, Ordering::Relaxed);
    }

    /// Count one frame-grammar violation from this peer, on both the
    /// per-connection row and the aggregate `wire.bad_frames` cell.
    fn bad_frame(&self, what: &str) {
        self.bad_frames.fetch_add(1, Ordering::Relaxed);
        self.obs.bad_frames.inc();
        self.events.emit(
            "conn.bad_frame",
            &[
                ("conn", Json::Num(self.id as f64)),
                ("peer", Json::Str(self.peer.clone())),
                ("what", Json::Str(what.into())),
            ],
        );
    }

    /// A session was granted over this connection.
    fn session_opened(&self) {
        self.sessions_open.fetch_add(1, Ordering::Relaxed);
        self.sessions_opened.fetch_add(1, Ordering::Relaxed);
        self.obs.sessions_open.add(1.0);
        self.obs.sessions_opened.inc();
    }

    /// A session on this connection ended (detach, disconnect, or a
    /// failed pump spawn that never ran).
    fn session_closed(&self) {
        self.sessions_open.fetch_sub(1, Ordering::Relaxed);
        self.obs.sessions_open.add(-1.0);
    }

    fn close(&self) {
        // relaxed: the swap is a pure at-most-once gate — close() has
        // several racing callers (reader teardown, writer errors, the
        // slow-reader policy, the reaper, server drop) and the
        // open-connection gauge must move exactly once; no other memory
        // is published through this flag.
        if !self.closed.swap(true, Ordering::Relaxed) {
            self.obs.conns_open.add(-1.0);
        }
        // shutdown() reaches the reader's and writer's clones through
        // the shared socket; dropping the handle then frees this fd.
        if let Some(s) = self.stream.lock().unwrap().take() {
            let _ = s.shutdown(Shutdown::Both);
        }
    }

    fn stats(&self) -> ConnStats {
        ConnStats {
            id: self.id,
            peer: self.peer.clone(),
            sessions_open: self.sessions_open.load(Ordering::Relaxed),
            sessions_opened: self.sessions_opened.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            bad_frames: self.bad_frames.load(Ordering::Relaxed),
            dropped_slow: self.dropped_slow.load(Ordering::Relaxed),
            reaped: self.reaped.load(Ordering::Relaxed),
            closed: self.closed.load(Ordering::Relaxed),
        }
    }
}

/// An env session parked after its connection died, awaiting a
/// `RESUME` within [`WireConfig::park_ttl_ticks`]. Holding the
/// [`Session`] keeps the lease (and so the frozen shard state) alive;
/// dropping the entry releases it.
struct ParkedSession {
    session: Session,
    /// The grant's opaque resume token; a `RESUME` must echo it.
    token: u64,
    /// Step frames this session's pump committed to the wire (the seed
    /// view counts). `RESUME` reconciles the client's `delivered`
    /// against this to decide between replaying the last step and
    /// accepting a re-submission — exactly-once either way.
    applied: u64,
    obs_floats: usize,
    /// Milliseconds-since-epoch after which the park expires.
    deadline_ms: u64,
}

/// Parked sessions held at once before the earliest-deadline entry is
/// evicted (its lease releases) to make room — parking must never grow
/// without bound under connection churn.
const MAX_PARKED: usize = 1024;

struct WireShared {
    sim: Arc<SimServer>,
    cfg: WireConfig,
    conns: Mutex<Vec<Arc<ConnShared>>>,
    next_conn: AtomicU64,
    next_session: AtomicU64,
    shutting_down: AtomicBool,
    /// Epoch of every connection's idle clock.
    epoch: Instant,
    /// Per-process secret folded into resume tokens, so tokens from a
    /// previous server incarnation never validate against this one.
    nonce: u64,
    /// Sessions parked for resume, keyed by wire session id.
    parked: Mutex<HashMap<u64, ParkedSession>>,
    /// Aggregate wire cells on the sim server's registry.
    obs: WireObs,
    events: Arc<EventLog>,
    trace: Arc<TraceSink>,
}

/// Mint the opaque resume token a grant carries (splitmix64 over the
/// wire id and the server nonce): unguessable enough that a stray
/// client cannot reclaim someone else's parked lease by id alone, with
/// no per-session secret state to store.
fn mint_token(shared: &WireShared, wire_id: u64) -> u64 {
    let mut z = shared
        .nonce
        .wrapping_add(wire_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Closed connections whose stats rows are kept for post-mortems; older
/// closed rows are pruned so a churny long-running server doesn't grow
/// (open connections are never pruned).
const RETAINED_CLOSED_CONNS: usize = 256;

/// The TCP front-end (see module docs). Dropping it stops accepting,
/// closes every connection, and thereby detaches all remote sessions.
pub struct WireServer {
    shared: Arc<WireShared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl WireServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7447"`, port 0 for ephemeral) and
    /// serve `sim` with the default [`WireConfig`].
    pub fn listen(addr: &str, sim: Arc<SimServer>) -> Result<WireServer> {
        WireServer::listen_with(addr, sim, WireConfig::default())
    }

    /// [`listen`](WireServer::listen) with explicit backpressure knobs.
    pub fn listen_with(addr: &str, sim: Arc<SimServer>, cfg: WireConfig) -> Result<WireServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        // Nonblocking accept + poll: shutdown must never depend on one
        // more connection arriving (a blocked accept has no other
        // reliable wake-up path).
        listener
            .set_nonblocking(true)
            .context("listener nonblocking")?;
        let local = listener.local_addr().context("local_addr")?;
        let obs = WireObs::new(&sim.registry());
        let events = sim.events();
        let trace = sim.trace();
        let nonce = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5EED)
            | 1;
        let shared = Arc::new(WireShared {
            sim,
            cfg,
            conns: Mutex::new(Vec::new()),
            next_conn: AtomicU64::new(0),
            next_session: AtomicU64::new(0),
            shutting_down: AtomicBool::new(false),
            epoch: Instant::now(),
            nonce,
            parked: Mutex::new(HashMap::new()),
            obs,
            events,
            trace,
        });
        let for_accept = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("bps-wire-accept".into())
            .spawn(move || accept_loop(listener, for_accept))
            .context("spawn accept thread")?;
        Ok(WireServer {
            shared,
            addr: local,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves port 0 binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stats rows for every open connection plus the most recent closed
    /// ones (older closed rows are pruned past a retention cap) — the
    /// wire-level counterpart of `SimServer::stats`.
    pub fn conn_stats(&self) -> Vec<ConnStats> {
        self.shared
            .conns
            .lock()
            .unwrap()
            .iter()
            .map(|c| c.stats())
            .collect()
    }

    /// Connections accepted over the server's lifetime (not subject to
    /// the closed-row pruning, so "has anyone ever connected" checks —
    /// e.g. `bps serve --once` — stay exact).
    pub fn accepted(&self) -> u64 {
        self.shared.next_conn.load(Ordering::Relaxed)
    }

    /// Sessions currently parked awaiting resume (DESIGN.md §0.12).
    /// `bps serve --once` holds its exit while this is nonzero: after an
    /// injected (or real) connection kill, every conn is momentarily
    /// closed while the client backs off, and without this check the
    /// smoke server would read that window as "all clients done".
    pub fn parked_open(&self) -> usize {
        self.shared.parked.lock().unwrap().len()
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        // The accept loop polls the flag (nonblocking listener), so the
        // join is bounded by one poll interval.
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for c in self.shared.conns.lock().unwrap().iter() {
            c.close();
        }
        // Release parked leases: nothing can resume past server drop.
        self.shared.parked.lock().unwrap().clear();
        self.shared.obs.park_open.set(0.0);
    }
}

/// How often the (nonblocking) accept loop re-checks for connections
/// and the shutdown flag.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

// Watchdog thresholds per wire role. The accept loop beats every poll,
// so it runs the tightest bounds; the writer beats at least once per
// recv timeout; reader and pump threads legitimately park unboundedly
// on an idle peer (they mark [`Heartbeat::idle`] first), so their
// thresholds only police the *working* intervals between parks.
const ACCEPT_DEGRADED: Duration = Duration::from_secs(1);
const ACCEPT_STALLED: Duration = Duration::from_secs(5);
const WRITER_DEGRADED: Duration = Duration::from_secs(2);
const WRITER_STALLED: Duration = Duration::from_secs(10);
const PUMP_DEGRADED: Duration = Duration::from_secs(5);
const PUMP_STALLED: Duration = Duration::from_secs(30);

fn accept_loop(listener: TcpListener, shared: Arc<WireShared>) {
    let hb = shared
        .sim
        .watchdog()
        .register("wire-accept", ACCEPT_DEGRADED, ACCEPT_STALLED);
    loop {
        hb.beat();
        if shared.shutting_down.load(Ordering::SeqCst) {
            return;
        }
        reap_idle_conns(&shared);
        reap_parked(&shared);
        let (stream, peer) = match listener.accept() {
            Ok(x) => x,
            // WouldBlock (no pending connection) or a transient error:
            // sleep one poll interval and re-check the shutdown flag.
            Err(_) => {
                std::thread::sleep(ACCEPT_POLL);
                continue;
            }
        };
        // Accepted sockets can inherit the listener's nonblocking mode
        // on some platforms; the per-connection threads use blocking IO.
        if stream.set_nonblocking(false).is_err() {
            continue;
        }
        let _ = stream.set_nodelay(true);
        // One clone for the shutdown handle, one for the writer; the
        // reader owns the original.
        let (shutdown_handle, writer_stream) = match (stream.try_clone(), stream.try_clone()) {
            (Ok(a), Ok(b)) => (a, b),
            _ => continue,
        };
        let id = shared.next_conn.fetch_add(1, Ordering::Relaxed) + 1;
        shared.obs.conns_accepted.inc();
        shared.obs.conns_open.add(1.0);
        let conn = Arc::new(ConnShared {
            id,
            peer: peer.to_string(),
            stream: Mutex::new(Some(shutdown_handle)),
            frames_in: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            frames_out: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            bad_frames: AtomicU64::new(0),
            sessions_open: AtomicU64::new(0),
            sessions_opened: AtomicU64::new(0),
            dropped_slow: AtomicBool::new(false),
            reaped: AtomicBool::new(false),
            closed: AtomicBool::new(false),
            epoch: shared.epoch,
            last_activity_ms: AtomicU64::new(shared.epoch.elapsed().as_millis() as u64),
            obs: shared.obs.clone(),
            events: Arc::clone(&shared.events),
            trace: Arc::clone(&shared.trace),
            fault: shared.cfg.fault.clone(),
        });
        {
            let mut conns = shared.conns.lock().unwrap();
            // prune the oldest closed rows past the retention cap
            let closed = conns
                .iter()
                .filter(|c| c.closed.load(Ordering::Relaxed))
                .count();
            if closed > RETAINED_CLOSED_CONNS {
                let mut to_drop = closed - RETAINED_CLOSED_CONNS;
                conns.retain(|c| {
                    // relaxed: `closed` is monotonic (false→true once);
                    // a stale read keeps a row one prune round longer,
                    // which only delays bookkeeping
                    if to_drop > 0 && c.closed.load(Ordering::Relaxed) {
                        to_drop -= 1;
                        false
                    } else {
                        true
                    }
                });
            }
            conns.push(Arc::clone(&conn));
        }
        let (outbox_tx, outbox_rx) = sync_channel::<Vec<u8>>(shared.cfg.outbox_frames);
        let writer_conn = Arc::clone(&conn);
        let writer_hb = shared
            .sim
            .watchdog()
            .register("wire-writer", WRITER_DEGRADED, WRITER_STALLED);
        let writer = std::thread::Builder::new()
            .name("bps-wire-writer".into())
            .spawn(move || writer_loop(writer_stream, outbox_rx, writer_conn, writer_hb));
        if writer.is_err() {
            conn.close();
            continue;
        }
        let reader_shared = Arc::clone(&shared);
        let reader_conn = Arc::clone(&conn);
        let reader = std::thread::Builder::new()
            .name("bps-wire-conn".into())
            .spawn(move || reader_loop(stream, outbox_tx, reader_conn, reader_shared));
        if reader.is_err() {
            // writer exits once the outbox sender is gone
            conn.close();
        }
    }
}

/// Close connections idle past [`WireConfig::idle_timeout_ticks`]
/// (checked once per accept-loop iteration). The close unblocks the
/// reader, whose teardown releases every lease the peer held.
fn reap_idle_conns(shared: &Arc<WireShared>) {
    let Some(ticks) = shared.cfg.idle_timeout_ticks else {
        return;
    };
    let now_ms = shared.epoch.elapsed().as_millis() as u64;
    for c in shared.conns.lock().unwrap().iter() {
        // relaxed: both flags are advisory — a stale `closed` or
        // `last_activity_ms` read defers the reap to the next accept-loop
        // iteration (25 ms later); nothing is published through them
        if !c.closed.load(Ordering::Relaxed)
            && now_ms.saturating_sub(c.last_activity_ms.load(Ordering::Relaxed)) > ticks
        {
            // relaxed: at-most-once gate for the reap bookkeeping; the
            // close() below is idempotent either way
            if !c.reaped.swap(true, Ordering::Relaxed) {
                c.obs.reaped.inc();
                c.events.emit(
                    "conn.idle_reap",
                    &[
                        ("conn", Json::Num(c.id as f64)),
                        ("peer", Json::Str(c.peer.clone())),
                        ("idle_ticks", Json::Num(ticks as f64)),
                    ],
                );
            }
            c.close();
        }
    }
}

/// Release parked sessions whose TTL ran out (checked once per
/// accept-loop iteration). Dropping the entry drops its [`Session`],
/// which detaches the lease — the slots fall back to the auto-reset
/// filler exactly as an ordinary disconnect would have.
fn reap_parked(shared: &Arc<WireShared>) {
    if shared.cfg.park_ttl_ticks.is_none() {
        return;
    }
    let now_ms = shared.epoch.elapsed().as_millis() as u64;
    let mut parked = shared.parked.lock().unwrap();
    let before = parked.len();
    parked.retain(|wire_id, p| {
        if p.deadline_ms <= now_ms {
            shared.obs.park_expired.inc();
            shared.events.emit(
                "conn.park_expired",
                &[("session", Json::Num(*wire_id as f64))],
            );
            false
        } else {
            true
        }
    });
    if parked.len() != before {
        shared.obs.park_open.set(parked.len() as f64);
    }
}

/// Park a session whose connection died, keeping its lease alive for a
/// `RESUME` within the TTL. Always consumes the session: `true` means
/// it was parked, `false` (parking off, or the server shutting down)
/// means it was dropped — which detaches the lease as before.
fn park_session(
    shared: &WireShared,
    wire_id: u64,
    session: Session,
    token: u64,
    applied: u64,
    obs_floats: usize,
) -> bool {
    let Some(ttl) = shared.cfg.park_ttl_ticks else {
        return false;
    };
    if shared.shutting_down.load(Ordering::SeqCst) {
        return false;
    }
    let now_ms = shared.epoch.elapsed().as_millis() as u64;
    let mut parked = shared.parked.lock().unwrap();
    if parked.len() >= MAX_PARKED {
        // Park-slot exhaustion: evict the entry closest to expiry (it
        // had the least time left to be reclaimed) rather than
        // declining the fresh park or growing without bound.
        if let Some(&victim) = parked
            .iter()
            .min_by_key(|(_, p)| p.deadline_ms)
            .map(|(id, _)| id)
        {
            parked.remove(&victim);
            shared.obs.park_expired.inc();
            shared.events.emit(
                "conn.park_evicted",
                &[("session", Json::Num(victim as f64))],
            );
        }
    }
    parked.insert(
        wire_id,
        ParkedSession {
            session,
            token,
            applied,
            obs_floats,
            deadline_ms: now_ms.saturating_add(ttl),
        },
    );
    shared.obs.park_parked.inc();
    shared.obs.park_open.set(parked.len() as f64);
    shared.events.emit(
        "conn.park",
        &[
            ("session", Json::Num(wire_id as f64)),
            ("ttl_ms", Json::Num(ttl as f64)),
        ],
    );
    true
}

/// Drain the outbox onto the socket. The periodic timeout lets the
/// writer notice a closed connection even while pumps still hold
/// outbox senders (e.g. blocked on an in-flight step).
fn writer_loop(mut stream: TcpStream, rx: Receiver<Vec<u8>>, conn: Arc<ConnShared>, hb: Heartbeat) {
    loop {
        hb.beat();
        match rx.recv_timeout(Duration::from_millis(500)) {
            Ok(mut buf) => {
                // Fault-injection plane (`bps serve --fault`): delay,
                // corrupt, or cut this write. Corruption flips header
                // bytes, so the client *rejects* the frame (BadMagic)
                // rather than silently adopting garbage.
                if let Some(inj) = conn.fault.as_deref() {
                    if let Some(d) = inj.write_delay() {
                        std::thread::sleep(d);
                    }
                    inj.corrupt_frame(&mut buf);
                    if inj.should_drop_conn() {
                        conn.close();
                        return;
                    }
                }
                let flush_from = if conn.trace.enabled() {
                    Some(conn.trace.now_us())
                } else {
                    None
                };
                let wrote_at = Instant::now();
                if std::io::Write::write_all(&mut stream, &buf).is_err() {
                    conn.close();
                    return;
                }
                let flush_d = wrote_at.elapsed();
                conn.obs.flush_us.observe(flush_d.as_micros() as u64);
                if let Some(from) = flush_from {
                    conn.trace
                        .span(WIRE_PID, "flush", "wire.flush", from, flush_d, 0);
                }
                conn.frames_out.fetch_add(1, Ordering::Relaxed);
                conn.bytes_out.fetch_add(buf.len() as u64, Ordering::Relaxed);
                conn.obs.frames_out.inc();
                conn.obs.bytes_out.add(buf.len() as u64);
                conn.touch();
            }
            Err(RecvTimeoutError::Timeout) => {
                // relaxed: exit poll only — a stale read costs one more
                // timeout tick before the writer notices the close
                if conn.closed.load(Ordering::Relaxed) {
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// Best-effort final error frame, written straight onto the socket with
/// a short timeout — for policy disconnects whose outbox can no longer
/// carry it (it is full, or its writer is gone). The write may race the
/// writer thread's last in-flight frame and interleave; the peer then
/// sees a framing error instead of the farewell, which is still a
/// diagnosable close, not a silent one. Never blocks teardown.
fn farewell_error(conn: &ConnShared, code: u16, msg: &str) {
    let stream = {
        let guard = conn.stream.lock().unwrap();
        guard.as_ref().and_then(|s| s.try_clone().ok())
    };
    if let Some(mut s) = stream {
        let _ = s.set_write_timeout(Some(Duration::from_millis(250)));
        let mut buf = Vec::new();
        frame::encode(
            &Frame::Error {
                re: 0,
                code,
                msg: msg.into(),
            },
            &mut buf,
        );
        let _ = std::io::Write::write_all(&mut s, &buf);
        conn.obs.errors_out.inc();
    }
}

/// Push an already-encoded frame into the connection's bounded outbox.
/// `false` means the connection is gone — either it already closed, or
/// it just earned a slow-reader disconnect because the outbox is full.
fn enqueue_buf(conn: &ConnShared, outbox: &SyncSender<Vec<u8>>, buf: Vec<u8>) -> bool {
    match outbox.try_send(buf) {
        Ok(()) => true,
        Err(TrySendError::Full(_)) => {
            // relaxed: at-most-once gate so the slow-reader event and
            // counter fire once; the actual disconnect is the close()
            // below, which is ordering-safe on its own
            if !conn.dropped_slow.swap(true, Ordering::Relaxed) {
                conn.obs.dropped_slow.inc();
                conn.events.emit(
                    "conn.slow_reader",
                    &[
                        ("conn", Json::Num(conn.id as f64)),
                        ("peer", Json::Str(conn.peer.clone())),
                    ],
                );
                // Never a silent close: tell the peer why, bypassing
                // the full outbox (DESIGN.md §0.12 error-frame table).
                farewell_error(
                    conn,
                    ERR_SLOW_READER,
                    "disconnected: slow reader (outbox overflow — drain step \
                     frames faster or lease fewer envs)",
                );
            }
            conn.close();
            false
        }
        Err(TrySendError::Disconnected(_)) => false,
    }
}

/// Serialize `f` into the connection's bounded outbox (see
/// [`enqueue_buf`] for the return contract). Error frames are counted
/// and logged here so every send site feeds the same cells.
fn enqueue(conn: &ConnShared, outbox: &SyncSender<Vec<u8>>, f: &Frame) -> bool {
    if let Frame::Error { re, code, msg } = f {
        conn.obs.errors_out.inc();
        conn.events.emit(
            "conn.error_frame",
            &[
                ("conn", Json::Num(conn.id as f64)),
                ("re", Json::Num(*re as f64)),
                ("code", Json::Num(*code as f64)),
                ("msg", Json::Str(msg.clone())),
            ],
        );
    }
    let mut buf = Vec::new();
    frame::encode(f, &mut buf);
    enqueue_buf(conn, outbox, buf)
}

/// Serialize a session's step view straight into the outbox — the wire
/// hot path: the observation megaframe is copied exactly once, from the
/// session's slices into the frame bytes (no intermediate owned view).
fn enqueue_step(
    conn: &ConnShared,
    outbox: &SyncSender<Vec<u8>>,
    wire_id: u64,
    obs_floats: usize,
    v: SessionView<'_>,
) -> bool {
    let encode_from = if conn.trace.enabled() {
        Some(conn.trace.now_us())
    } else {
        None
    };
    let started = Instant::now();
    let mut buf = Vec::new();
    frame::encode_step(
        &mut buf,
        wire_id,
        v.step,
        obs_floats as u32,
        StepRef {
            obs: v.obs,
            goal: v.goal,
            rewards: v.rewards,
            dones: v.dones,
            successes: v.successes,
            spl: v.spl,
            scores: v.scores,
        },
    );
    let encode_d = started.elapsed();
    conn.obs.encode_us.observe(encode_d.as_micros() as u64);
    if let Some(from) = encode_from {
        conn.trace
            .span(WIRE_PID, "encode", "wire.encode", from, encode_d, v.step);
    }
    enqueue_buf(conn, outbox, buf)
}

/// Byte-counting shim over the connection socket for `frame::read_frame`.
struct Metered<'a> {
    s: &'a TcpStream,
    conn: &'a ConnShared,
}

impl Read for Metered<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let mut s = self.s;
        let n = s.read(buf)?;
        self.conn.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
        self.conn.obs.bytes_in.add(n as u64);
        Ok(n)
    }
}

enum PumpMsg {
    Submit(Vec<(u32, u8)>),
    Detach,
}

/// What a wire session id routes to: a plain env session's pump inbox,
/// or a policy tenant's control plane (the agent pump owns the
/// trajectory stream; the reader only posts goals and detaches).
enum Route {
    Env(SyncSender<PumpMsg>),
    Agent(TenantControl),
}

fn reader_loop(
    stream: TcpStream,
    outbox: SyncSender<Vec<u8>>,
    conn: Arc<ConnShared>,
    shared: Arc<WireShared>,
) {
    let mut sessions: HashMap<u64, Route> = HashMap::new();
    let mut greeted = false;
    let hb = shared
        .sim
        .watchdog()
        .register("wire-reader", PUMP_DEGRADED, PUMP_STALLED);
    let mut metered = Metered {
        s: &stream,
        conn: &conn,
    };
    loop {
        // Direction-aware read: client→server frames are all small, so
        // a hostile length field cannot make this end allocate big. An
        // idle peer parks this thread unboundedly — deliberate, so the
        // watchdog must not read the park as a stall.
        hb.idle();
        let f = match frame::read_frame_dir(&mut metered, true) {
            Ok(f) => f,
            Err(ReadError::Eof) | Err(ReadError::Io(_)) => break,
            Err(ReadError::Wire(e)) => {
                // Malformed traffic: courtesy error frame, then hang up.
                conn.bad_frame(&e.to_string());
                let _ = enqueue(
                    &conn,
                    &outbox,
                    &Frame::Error {
                        re: 0,
                        code: e.code(),
                        msg: e.to_string(),
                    },
                );
                break;
            }
        };
        hb.beat();
        conn.frames_in.fetch_add(1, Ordering::Relaxed);
        conn.obs.frames_in.inc();
        conn.touch();
        if !greeted && !matches!(&f, Frame::Hello) {
            conn.bad_frame("expected HELLO");
            let _ = enqueue(
                &conn,
                &outbox,
                &Frame::Error {
                    re: 0,
                    code: ERR_PROTOCOL,
                    msg: "expected HELLO".into(),
                },
            );
            break;
        }
        match f {
            Frame::Hello => {
                if greeted {
                    conn.bad_frame("duplicate HELLO");
                    let _ = enqueue(
                        &conn,
                        &outbox,
                        &Frame::Error {
                            re: 0,
                            code: ERR_PROTOCOL,
                            msg: "duplicate HELLO".into(),
                        },
                    );
                    break;
                }
                greeted = true;
                let welcome = Frame::Welcome {
                    shards: shared.sim.num_shards() as u32,
                };
                if !enqueue(&conn, &outbox, &welcome) {
                    break;
                }
            }
            Frame::Lease { req, task, n_envs } => {
                match shared.sim.try_connect(task, n_envs as usize) {
                    Ok(session) => {
                        // Wire-level size guard: the session's submit,
                        // grant, and step frames must all fit the
                        // per-type caps, or every later exchange would
                        // be rejected as hostile — fail the lease now,
                        // diagnosably, instead.
                        let n = session.num_envs();
                        let step_bytes = 24 + n * (4 * session.obs_floats() + 26);
                        if n > frame::MAX_SESSION_ENVS || step_bytes > frame::MAX_FRAME {
                            drop(session); // releases the lease
                            let err = Frame::Error {
                                re: req,
                                code: ERR_LEASE,
                                msg: format!(
                                    "lease of {n} envs exceeds the wire transport's \
                                     frame caps (max {} envs and a {} MiB step view)",
                                    frame::MAX_SESSION_ENVS,
                                    frame::MAX_FRAME >> 20
                                ),
                            };
                            if !enqueue(&conn, &outbox, &err) {
                                break;
                            }
                            continue;
                        }
                        let wire_id = shared.next_session.fetch_add(1, Ordering::Relaxed) + 1;
                        let token = mint_token(&shared, wire_id);
                        let (tx, rx) = sync_channel(shared.cfg.inbox_submits.max(1));
                        conn.session_opened();
                        let ctx = PumpCtx {
                            session,
                            rx,
                            conn: Arc::clone(&conn),
                            outbox: outbox.clone(),
                            shared: Arc::clone(&shared),
                            wire_id,
                            req,
                            token,
                            // The seed view the pump sends with the
                            // grant is the first applied step frame.
                            applied: 1,
                            send_grant: true,
                            hb: shared.sim.watchdog().register(
                                "wire-session-pump",
                                PUMP_DEGRADED,
                                PUMP_STALLED,
                            ),
                        };
                        let spawned = std::thread::Builder::new()
                            .name("bps-wire-session".into())
                            .spawn(move || session_pump(ctx));
                        match spawned {
                            Ok(_) => {
                                sessions.insert(wire_id, Route::Env(tx));
                            }
                            Err(e) => {
                                // ctx (and the lease) died with the failed
                                // spawn; tell the client
                                conn.session_closed();
                                if !enqueue(
                                    &conn,
                                    &outbox,
                                    &Frame::Error {
                                        re: req,
                                        code: ERR_LEASE,
                                        msg: format!("spawn session pump: {e}"),
                                    },
                                ) {
                                    break;
                                }
                            }
                        }
                    }
                    Err(decline) => {
                        // Admission declines are never a disconnect, and
                        // overload (the memory-budget gate) is shed with
                        // a retry-after hint rather than a terminal
                        // lease rejection: capacity returns when a
                        // co-tenant detaches.
                        let (code, msg) = match decline {
                            LeaseDecline::Overload(m) => {
                                (ERR_RETRY_AFTER, with_retry_after(250, &m))
                            }
                            LeaseDecline::NoCapacity(m) => (ERR_LEASE, m),
                        };
                        if !enqueue(&conn, &outbox, &Frame::Error { re: req, code, msg }) {
                            break;
                        }
                    }
                }
            }
            Frame::Submit { session, pairs } => {
                enum SubmitOutcome {
                    Sent,
                    Flood,
                    Dead,
                    AgentRoute,
                    Unknown,
                }
                let outcome = match sessions.get(&session) {
                    Some(Route::Env(tx)) => match tx.try_send(PumpMsg::Submit(pairs)) {
                        Ok(()) => SubmitOutcome::Sent,
                        Err(TrySendError::Full(_)) => SubmitOutcome::Flood,
                        Err(TrySendError::Disconnected(_)) => SubmitOutcome::Dead,
                    },
                    Some(Route::Agent(_)) => SubmitOutcome::AgentRoute,
                    None => SubmitOutcome::Unknown,
                };
                match outcome {
                    SubmitOutcome::Sent => {}
                    SubmitOutcome::Flood => {
                        // Flood policy, mirror of the outbox bound — but
                        // shed, not disconnect: the excess submit is
                        // dropped and answered with a typed retry-after
                        // error; the connection and the lease survive.
                        // The bounded inbox still caps memory at
                        // inbox_submits frames, and because every
                        // session's inbox is its own bounded queue, one
                        // flooding tenant cannot starve its co-tenants'
                        // submits (round-robin fairness by construction).
                        shared.obs.shed_flood.inc();
                        conn.events.emit(
                            "overload.shed",
                            &[
                                ("conn", Json::Num(conn.id as f64)),
                                ("session", Json::Num(session as f64)),
                                ("what", Json::Str("submit_flood".into())),
                            ],
                        );
                        if !enqueue(
                            &conn,
                            &outbox,
                            &Frame::Error {
                                re: session,
                                code: ERR_RETRY_AFTER,
                                msg: with_retry_after(
                                    10,
                                    "submit shed: pipeline overflow (submitting \
                                     faster than the shard steps)",
                                ),
                            },
                        ) {
                            break;
                        }
                    }
                    SubmitOutcome::AgentRoute => {
                        // Server-driven lease: the client has no actions
                        // to submit. Report and keep the connection.
                        if !enqueue(
                            &conn,
                            &outbox,
                            &Frame::Error {
                                re: session,
                                code: ERR_SUBMIT,
                                msg: "submit on a policy-tenant session \
                                      (the server drives it; post GOAL instead)"
                                    .into(),
                            },
                        ) {
                            break;
                        }
                    }
                    SubmitOutcome::Dead | SubmitOutcome::Unknown => {
                        sessions.remove(&session);
                        // Well-formed frame, dead or unknown session id:
                        // report and keep the connection — other
                        // sessions on it are healthy.
                        if !enqueue(
                            &conn,
                            &outbox,
                            &Frame::Error {
                                re: session,
                                code: ERR_SESSION,
                                msg: "unknown session".into(),
                            },
                        ) {
                            break;
                        }
                    }
                }
            }
            Frame::Goal { session, steps } => {
                enum GoalOutcome {
                    Ok,
                    Rejected(String),
                    EnvRoute,
                    Unknown,
                }
                let outcome = match sessions.get(&session) {
                    Some(Route::Agent(control)) => match control.set_goal(steps) {
                        Ok(()) => GoalOutcome::Ok,
                        Err(e) => GoalOutcome::Rejected(format!("{e:#}")),
                    },
                    Some(Route::Env(_)) => GoalOutcome::EnvRoute,
                    None => GoalOutcome::Unknown,
                };
                // All goal failures keep the connection: the frame was
                // well-formed, and co-sessions on it are healthy.
                match outcome {
                    GoalOutcome::Ok => {}
                    GoalOutcome::Rejected(msg) => {
                        if !enqueue(
                            &conn,
                            &outbox,
                            &Frame::Error {
                                re: session,
                                code: ERR_SUBMIT,
                                msg,
                            },
                        ) {
                            break;
                        }
                    }
                    GoalOutcome::EnvRoute => {
                        if !enqueue(
                            &conn,
                            &outbox,
                            &Frame::Error {
                                re: session,
                                code: ERR_SUBMIT,
                                msg: "goal on a plain env session \
                                      (lease with LEASE_POLICY to be server-driven)"
                                    .into(),
                            },
                        ) {
                            break;
                        }
                    }
                    GoalOutcome::Unknown => {
                        if !enqueue(
                            &conn,
                            &outbox,
                            &Frame::Error {
                                re: session,
                                code: ERR_SESSION,
                                msg: "unknown session".into(),
                            },
                        ) {
                            break;
                        }
                    }
                }
            }
            Frame::LeasePolicy {
                req,
                task,
                n_envs,
                greedy,
                seed,
                variant,
            } => {
                let mode = if greedy {
                    ActionMode::Greedy
                } else {
                    ActionMode::Sample { seed }
                };
                match shared
                    .sim
                    .connect_with_policy_mode(task, n_envs as usize, &variant, mode)
                {
                    Ok(ts) => {
                        // Same wire-level size guard as a plain lease,
                        // against the TRAJ frame this lease will stream
                        // (one action byte per slot on top of the step
                        // view).
                        let n = ts.num_envs();
                        let traj_bytes = 24 + n * (4 * ts.obs_floats() + 27);
                        if n > frame::MAX_SESSION_ENVS || traj_bytes > frame::MAX_FRAME {
                            ts.detach();
                            let err = Frame::Error {
                                re: req,
                                code: ERR_LEASE,
                                msg: format!(
                                    "lease of {n} envs exceeds the wire transport's \
                                     frame caps (max {} envs and a {} MiB traj view)",
                                    frame::MAX_SESSION_ENVS,
                                    frame::MAX_FRAME >> 20
                                ),
                            };
                            if !enqueue(&conn, &outbox, &err) {
                                break;
                            }
                            continue;
                        }
                        let wire_id = shared.next_session.fetch_add(1, Ordering::Relaxed) + 1;
                        let token = mint_token(&shared, wire_id);
                        conn.session_opened();
                        let control = ts.control();
                        let ctx = AgentCtx {
                            ts,
                            conn: Arc::clone(&conn),
                            outbox: outbox.clone(),
                            wire_id,
                            req,
                            token,
                            hb: shared.sim.watchdog().register(
                                "wire-agent-pump",
                                PUMP_DEGRADED,
                                PUMP_STALLED,
                            ),
                        };
                        let spawned = std::thread::Builder::new()
                            .name("bps-wire-agent".into())
                            .spawn(move || agent_pump(ctx));
                        match spawned {
                            Ok(_) => {
                                sessions.insert(wire_id, Route::Agent(control));
                            }
                            Err(e) => {
                                // ctx (and the lease) died with the
                                // failed spawn; tell the client
                                conn.session_closed();
                                if !enqueue(
                                    &conn,
                                    &outbox,
                                    &Frame::Error {
                                        re: req,
                                        code: ERR_LEASE,
                                        msg: format!("spawn agent pump: {e}"),
                                    },
                                ) {
                                    break;
                                }
                            }
                        }
                    }
                    Err(e) => {
                        // Includes the vault-less case: the message names
                        // "no policy artifacts" so remote callers can
                        // tell config-missing from capacity-missing.
                        if !enqueue(
                            &conn,
                            &outbox,
                            &Frame::Error {
                                re: req,
                                code: ERR_LEASE,
                                msg: format!("{e:#}"),
                            },
                        ) {
                            break;
                        }
                    }
                }
            }
            Frame::Detach { session } => {
                let sent = match sessions.remove(&session) {
                    // Full can only mean the peer flooded the inbox and
                    // now wants out; teardown below detaches anyway.
                    Some(Route::Env(tx)) => match tx.try_send(PumpMsg::Detach) {
                        Ok(()) => true,
                        Err(TrySendError::Full(_)) => break,
                        Err(TrySendError::Disconnected(_)) => false,
                    },
                    // The agent pump notices the detach when its
                    // trajectory stream drains and sends the Detached
                    // ack itself.
                    Some(Route::Agent(control)) => {
                        control.detach();
                        true
                    }
                    None => false,
                };
                if !sent
                    && !enqueue(
                        &conn,
                        &outbox,
                        &Frame::Error {
                            re: session,
                            code: ERR_SESSION,
                            msg: "unknown session".into(),
                        },
                    )
                {
                    break;
                }
            }
            Frame::Stats { req } => {
                // A registry snapshot, rendered exactly as the
                // plaintext endpoint would serve it — remote scrapes
                // and `GET /metrics` see byte-identical expositions.
                let text = shared.sim.registry().snapshot().to_prometheus();
                if !enqueue(
                    &conn,
                    &outbox,
                    &Frame::StatsReply {
                        req,
                        version: SNAPSHOT_VERSION,
                        text,
                    },
                ) {
                    break;
                }
            }
            Frame::Dump { req } => {
                // Manual flight-recorder trigger from a remote operator.
                // Never fatal to the connection: an unarmed recorder or a
                // bundle-write failure is reported in the reply so `bps
                // stats ADDR --dump` can print a real diagnosis.
                let reply = match shared.sim.recorder() {
                    Some(rec) => match rec.trigger(Trigger::Manual) {
                        Ok(Some(path)) => Frame::DumpReply {
                            req,
                            ok: true,
                            msg: path.display().to_string(),
                        },
                        Ok(None) => Frame::DumpReply {
                            req,
                            ok: false,
                            msg: "dump suppressed (rate limit)".into(),
                        },
                        Err(e) => Frame::DumpReply {
                            req,
                            ok: false,
                            msg: format!("dump failed: {e}"),
                        },
                    },
                    None => Frame::DumpReply {
                        req,
                        ok: false,
                        msg: "flight recorder not armed (start bps serve with --dump-dir)".into(),
                    },
                };
                if !enqueue(&conn, &outbox, &reply) {
                    break;
                }
            }
            Frame::Resume {
                req,
                session,
                token,
                delivered,
            } => {
                let entry = shared.parked.lock().unwrap().remove(&session);
                match entry {
                    Some(p) if p.token == token => {
                        shared
                            .obs
                            .park_open
                            .set(shared.parked.lock().unwrap().len() as f64);
                        // FIFO discipline: RESUMED first, then the
                        // replayed step (if one is owed), and only then
                        // is the pump spawned — its frames follow ours
                        // through the same outbox. If the connection
                        // dies mid-handshake, re-park so a later
                        // reconnect can still reclaim the lease.
                        let resumed = Frame::Resumed {
                            req,
                            session,
                            applied: p.applied,
                        };
                        if !enqueue(&conn, &outbox, &resumed) {
                            shared.parked.lock().unwrap().insert(session, p);
                            break;
                        }
                        if p.applied > delivered
                            && !enqueue_step(&conn, &outbox, session, p.obs_floats, p.session.view())
                        {
                            // The applied-but-undelivered step replays
                            // from the session's frozen view; the shard
                            // did not advance past it while parked.
                            shared.parked.lock().unwrap().insert(session, p);
                            break;
                        }
                        shared.obs.resume_ok.inc();
                        conn.events.emit(
                            "conn.resume",
                            &[
                                ("conn", Json::Num(conn.id as f64)),
                                ("session", Json::Num(session as f64)),
                                ("applied", Json::Num(p.applied as f64)),
                                ("delivered", Json::Num(delivered as f64)),
                            ],
                        );
                        conn.session_opened();
                        let (tx, rx) = sync_channel(shared.cfg.inbox_submits.max(1));
                        let ctx = PumpCtx {
                            session: p.session,
                            rx,
                            conn: Arc::clone(&conn),
                            outbox: outbox.clone(),
                            shared: Arc::clone(&shared),
                            wire_id: session,
                            req,
                            token: p.token,
                            applied: p.applied,
                            send_grant: false,
                            hb: shared.sim.watchdog().register(
                                "wire-session-pump",
                                PUMP_DEGRADED,
                                PUMP_STALLED,
                            ),
                        };
                        let spawned = std::thread::Builder::new()
                            .name("bps-wire-session".into())
                            .spawn(move || session_pump(ctx));
                        match spawned {
                            Ok(_) => {
                                sessions.insert(session, Route::Env(tx));
                            }
                            Err(e) => {
                                conn.session_closed();
                                if !enqueue(
                                    &conn,
                                    &outbox,
                                    &Frame::Error {
                                        re: session,
                                        code: ERR_SESSION,
                                        msg: format!("spawn session pump: {e}"),
                                    },
                                ) {
                                    break;
                                }
                            }
                        }
                    }
                    Some(p) => {
                        // Wrong token: not the owner. Re-park untouched
                        // so the rightful client's window stays open.
                        shared.parked.lock().unwrap().insert(session, p);
                        shared.obs.resume_fail.inc();
                        if !enqueue(
                            &conn,
                            &outbox,
                            &Frame::Error {
                                re: req,
                                code: ERR_SESSION,
                                msg: "resume refused: token mismatch".into(),
                            },
                        ) {
                            break;
                        }
                    }
                    None => {
                        shared.obs.resume_fail.inc();
                        if !enqueue(
                            &conn,
                            &outbox,
                            &Frame::Error {
                                re: req,
                                code: ERR_SESSION,
                                msg: "resume refused: unknown or expired session \
                                      (park TTL elapsed, parking disabled, or \
                                      already resumed)"
                                    .into(),
                            },
                        ) {
                            break;
                        }
                    }
                }
            }
            Frame::Welcome { .. }
            | Frame::Grant { .. }
            | Frame::Step { .. }
            | Frame::Traj { .. }
            | Frame::Detached { .. }
            | Frame::Error { .. }
            | Frame::StatsReply { .. }
            | Frame::DumpReply { .. }
            | Frame::Resumed { .. } => {
                conn.bad_frame("client sent a server-only frame");
                let _ = enqueue(
                    &conn,
                    &outbox,
                    &Frame::Error {
                        re: 0,
                        code: ERR_PROTOCOL,
                        msg: "client sent a server-only frame".into(),
                    },
                );
                break;
            }
        }
    }
    // Dropping the pump senders detaches every env session this
    // connection leased; agent routes are detached explicitly (their
    // pumps hold control clones, so a plain drop would not release the
    // lease). Slots fall back to the auto-reset filler either way.
    for (_, route) in sessions.drain() {
        if let Route::Agent(control) = route {
            control.detach();
        }
    }
    drop(sessions);
    conn.close();
}

struct AgentCtx {
    ts: TenantSession,
    conn: Arc<ConnShared>,
    outbox: SyncSender<Vec<u8>>,
    wire_id: u64,
    req: u64,
    /// Minted like a plain session's resume token so the GRANT shape is
    /// uniform, but agent leases are never parked — a dropped connection
    /// releases the tenancy (its goal/recurrent state is server-side and
    /// not reconstructible by a reconnecting client).
    token: u64,
    hb: Heartbeat,
}

/// Serialize a tenant trajectory step straight into the outbox — the
/// agent-route twin of [`enqueue_step`] (one copy, no owned frame).
fn enqueue_traj(
    conn: &ConnShared,
    outbox: &SyncSender<Vec<u8>>,
    wire_id: u64,
    obs_floats: usize,
    ts: &TrajStep,
) -> bool {
    let encode_from = if conn.trace.enabled() {
        Some(conn.trace.now_us())
    } else {
        None
    };
    let started = Instant::now();
    let mut buf = Vec::new();
    frame::encode_traj(
        &mut buf,
        wire_id,
        ts.step,
        obs_floats as u32,
        &ts.actions,
        StepRef {
            obs: &ts.obs,
            goal: &ts.goal,
            rewards: &ts.rewards,
            dones: &ts.dones,
            successes: &ts.successes,
            spl: &ts.spl,
            scores: &ts.scores,
        },
    );
    let encode_d = started.elapsed();
    conn.obs.encode_us.observe(encode_d.as_micros() as u64);
    if let Some(from) = encode_from {
        conn.trace
            .span(WIRE_PID, "encode", "wire.encode", from, encode_d, ts.step);
    }
    enqueue_buf(conn, outbox, buf)
}

/// Owns one remote policy tenancy server-side: grants the lease, seeds
/// the client with the initial observation snapshot, then forwards the
/// server-driven trajectory stream. The reader never blocks on this
/// session — goals route through [`TenantControl`] inline.
fn agent_pump(ctx: AgentCtx) {
    let AgentCtx {
        mut ts,
        conn,
        outbox,
        wire_id,
        req,
        token,
        hb,
    } = ctx;
    let of = ts.obs_floats();
    let grant = Frame::Grant {
        req,
        session: wire_id,
        token,
        task: ts.task(),
        obs_floats: of as u32,
        slots: ts.slots().iter().map(|&s| s as u32).collect(),
    };
    // Grant, then the initial snapshot as a plain Step frame (no actions
    // were stepped yet) — exactly what a plain lease's client sees.
    let init = ts.initial();
    let mut alive = enqueue(&conn, &outbox, &grant)
        && {
            let mut buf = Vec::new();
            frame::encode_step(
                &mut buf,
                wire_id,
                init.step,
                of as u32,
                StepRef {
                    obs: &init.obs,
                    goal: &init.goal,
                    rewards: &init.rewards,
                    dones: &init.dones,
                    successes: &init.successes,
                    spl: &init.spl,
                    scores: &init.scores,
                },
            );
            enqueue_buf(&conn, &outbox, buf)
        };
    let mut clean_detach = false;
    while alive {
        // The stream blocks until the tenant driver's next tick (possibly
        // forever if the goal is met and the peer holds the lease idle) —
        // a stalled *driver* is attributed to its own heartbeat, not this
        // pump's.
        hb.idle();
        let next = ts.next_step();
        hb.beat();
        match next {
            Ok(Some(step)) => {
                alive = enqueue_traj(&conn, &outbox, wire_id, of, &step);
            }
            Ok(None) => {
                clean_detach = true;
                break;
            }
            Err(e) => {
                let _ = enqueue(
                    &conn,
                    &outbox,
                    &Frame::Error {
                        re: wire_id,
                        code: ERR_SHARD,
                        msg: format!("{e:#}"),
                    },
                );
                alive = false;
            }
        }
    }
    ts.detach();
    if clean_detach {
        let _ = enqueue(&conn, &outbox, &Frame::Detached { session: wire_id });
    }
    conn.session_closed();
}

struct PumpCtx {
    session: Session,
    rx: Receiver<PumpMsg>,
    conn: Arc<ConnShared>,
    outbox: SyncSender<Vec<u8>>,
    shared: Arc<WireShared>,
    wire_id: u64,
    req: u64,
    /// Resume token minted with the grant; proves ownership on RESUME.
    token: u64,
    /// Step frames *committed* for this session, counting the seed. A
    /// step counts the moment its `ticket.wait()` returns — before the
    /// delivery attempt — so a resume can tell replay from re-submit.
    applied: u64,
    /// False on a resume re-spawn: the client already holds the grant
    /// and the seed, so the pump starts straight at the submit loop.
    send_grant: bool,
    hb: Heartbeat,
}

/// Why a session pump stopped — decides what happens to the lease.
enum PumpExit {
    /// Client detached deliberately: release the lease, ack `DETACHED`.
    Clean,
    /// Shard/session failure, already reported as an error frame:
    /// release the lease; there is nothing left to resume.
    Failed,
    /// The connection died under the session: park the lease for a
    /// resume window instead of releasing it (when parking is on).
    ConnDead,
}

/// Report a shard-side failure on the session's stream. A quarantined
/// shard gets the typed `ERR_SHARD_DOWN` plus a retry-after hint — the
/// lease is gone either way, but the server may heal the shard and a
/// client can re-lease after the hint. Anything else stays `ERR_SHARD`.
fn shard_failure(
    conn: &ConnShared,
    outbox: &SyncSender<Vec<u8>>,
    session: &Session,
    wire_id: u64,
    e: anyhow::Error,
) -> PumpExit {
    let (code, msg) = if session.shard_quarantined() {
        (ERR_SHARD_DOWN, with_retry_after(1000, &format!("{e:#}")))
    } else {
        (ERR_SHARD, format!("{e:#}"))
    };
    let _ = enqueue(
        conn,
        outbox,
        &Frame::Error {
            re: wire_id,
            code,
            msg,
        },
    );
    PumpExit::Failed
}

/// Owns one remote session server-side: grants the lease, then turns
/// each routed `Submit` into a `submit_at → wait → Step` cycle. Exits
/// when the client detaches, the connection dies (parking the lease if
/// a resume window is configured), or the shard fails.
fn session_pump(ctx: PumpCtx) {
    let PumpCtx {
        mut session,
        rx,
        conn,
        outbox,
        shared,
        wire_id,
        req,
        token,
        mut applied,
        send_grant,
        hb,
    } = ctx;
    let of = session.obs_floats();
    let mut exit: Option<PumpExit> = None;
    if send_grant {
        let grant = Frame::Grant {
            req,
            session: wire_id,
            token,
            task: session.task(),
            obs_floats: of as u32,
            slots: session.slots().iter().map(|&s| s as u32).collect(),
        };
        // Grant, then seed the client's buffers with the latest published
        // step so its `view()` works before the first submit.
        if !(enqueue(&conn, &outbox, &grant)
            && enqueue_step(&conn, &outbox, wire_id, of, session.view()))
        {
            exit = Some(PumpExit::ConnDead);
        }
    }
    while exit.is_none() {
        // A lease held idle by the client parks here unboundedly — mark
        // the park deliberate so the watchdog polices only the working
        // submit→wait→encode interval.
        hb.idle();
        let msg = rx.recv();
        hb.beat();
        match msg {
            Ok(PumpMsg::Submit(pairs)) => {
                let slots: Vec<usize> = pairs.iter().map(|&(s, _)| s as usize).collect();
                let actions: Vec<u8> = pairs.iter().map(|&(_, a)| a).collect();
                match session.submit_at(&slots, &actions) {
                    Ok((accepted, ticket)) => {
                        if accepted < slots.len() {
                            // Some slot indices were bad (out of range,
                            // unleased, or foreign) — the coalescer
                            // skipped them. Log what the peer tried.
                            conn.events.emit(
                                "conn.bad_submit",
                                &[
                                    ("conn", Json::Num(conn.id as f64)),
                                    ("session", Json::Num(wire_id as f64)),
                                    ("requested", Json::Num(slots.len() as f64)),
                                    ("accepted", Json::Num(accepted as f64)),
                                ],
                            );
                        }
                        if accepted == 0 {
                            // Nothing was buffered (every slot index was
                            // bad): waiting could hang forever, so report
                            // instead.
                            drop(ticket);
                            if !enqueue(
                                &conn,
                                &outbox,
                                &Frame::Error {
                                    re: wire_id,
                                    code: ERR_SUBMIT,
                                    msg: "no acceptable slots in submit".into(),
                                },
                            ) {
                                exit = Some(PumpExit::ConnDead);
                            }
                            continue;
                        }
                        match ticket.wait() {
                            Ok(v) => {
                                // Committed server-side the moment the
                                // wait returns: count it *before* the
                                // delivery attempt, so a resume after a
                                // mid-enqueue disconnect replays this
                                // step instead of double-stepping.
                                applied += 1;
                                if !enqueue_step(&conn, &outbox, wire_id, of, v) {
                                    exit = Some(PumpExit::ConnDead);
                                }
                            }
                            Err(e) => {
                                exit = Some(shard_failure(&conn, &outbox, &session, wire_id, e));
                            }
                        }
                    }
                    Err(e) => {
                        exit = Some(shard_failure(&conn, &outbox, &session, wire_id, e));
                    }
                }
            }
            Ok(PumpMsg::Detach) => exit = Some(PumpExit::Clean),
            Err(_) => exit = Some(PumpExit::ConnDead), // connection reader is gone
        }
    }
    match exit.unwrap_or(PumpExit::Failed) {
        PumpExit::Clean => {
            session.detach();
            // Acked *after* the release, so a client that waits for this
            // can immediately re-lease the freed slots.
            let _ = enqueue(&conn, &outbox, &Frame::Detached { session: wire_id });
        }
        PumpExit::Failed => session.detach(),
        PumpExit::ConnDead => {
            // Dead peer: park the lease for a resume window rather than
            // releasing it. `park_session` declines — dropping (and thus
            // detaching) the session — when parking is off, the server is
            // shutting down, or the table is full past eviction.
            park_session(&shared, wire_id, session, token, applied, of);
        }
    }
    conn.session_closed();
}
