//! The wire frame grammar: length-prefixed binary frames, no external
//! dependencies (see DESIGN.md §0.8 for the full table).
//!
//! Every frame is an 8-byte header followed by `len` payload bytes, all
//! little-endian:
//!
//! ```text
//! [magic u16 = 0xB50C][version u8 = 1][type u8][len u32]  payload[len]
//! ```
//!
//! The header is validated *before* any payload byte is read or any
//! buffer is allocated, so a hostile length field cannot balloon memory:
//! bad magic, unknown version, unknown frame type, and `len > MAX_FRAME`
//! are all rejected from the fixed-size header alone. Payload decoding is
//! pure slice arithmetic over the already-bounded buffer — every count
//! field is checked against the remaining bytes, so truncated or
//! internally inconsistent payloads produce [`WireError`]s, never panics
//! or over-reads.
//!
//! Decoding is the exact inverse of encoding (round-trip asserted in the
//! unit tests below); observation floats travel as raw IEEE-754 bits, so
//! a remote view is bitwise identical to the in-process one
//! (`rust/tests/serve_remote.rs`).

use std::io::{Read, Write};

use crate::sim::Task;

/// First two bytes of every frame.
pub const MAGIC: u16 = 0xB50C;
/// Protocol version carried in every header.
pub const VERSION: u8 = 1;
/// Header bytes before the payload.
pub const HEADER_LEN: usize = 8;
/// Upper bound on a payload; larger length fields are hostile (a 64-env
/// RGB-128 step view is ~12 MB, so 64 MiB leaves generous headroom).
pub const MAX_FRAME: usize = 64 << 20;

// Frame types.
pub const FT_HELLO: u8 = 1;
pub const FT_WELCOME: u8 = 2;
pub const FT_LEASE: u8 = 3;
pub const FT_GRANT: u8 = 4;
pub const FT_SUBMIT: u8 = 5;
pub const FT_STEP: u8 = 6;
pub const FT_DETACH: u8 = 7;
pub const FT_DETACHED: u8 = 8;
pub const FT_ERROR: u8 = 9;
// Policy-tenant frames (DESIGN.md §0.9): lease slots + a server-side
// policy, post goals, stream server-driven trajectories back.
pub const FT_LEASE_POLICY: u8 = 10;
pub const FT_GOAL: u8 = 11;
pub const FT_TRAJ: u8 = 12;
// Observability frames (DESIGN.md §0.10): scrape the server's metrics
// registry over the session connection.
pub const FT_STATS: u8 = 13;
pub const FT_STATS_REPLY: u8 = 14;
// Flight-recorder frames (DESIGN.md §0.11): ask the server to write an
// incident bundle (`bps stats ADDR --dump`).
pub const FT_DUMP: u8 = 15;
pub const FT_DUMP_REPLY: u8 = 16;
// Fault-tolerance frames (DESIGN.md §0.12): reattach to a lease parked
// by the server when its connection dropped.
pub const FT_RESUME: u8 = 17;
pub const FT_RESUMED: u8 = 18;

// Error-frame codes (the `code` field of `Frame::Error`). The code also
// disambiguates what the `re` field names: `ERR_LEASE` refers to a
// client-chosen lease `req` id; `ERR_SESSION`/`ERR_SUBMIT`/`ERR_SHARD`/
// `ERR_SHARD_DOWN` refer to a server-chosen wire session id (the two id
// spaces can collide numerically). Codes 1–2 and `ERR_SLOW_READER` are
// connection-level (`re` = 0). Policy disconnects are never silent: a
// slow-reader close is preceded by a best-effort [`ERR_SLOW_READER`]
// farewell written directly to the socket (the outbox is full by
// definition), and every shed answer carries [`ERR_RETRY_AFTER`].
/// Malformed frame; the server closes the connection after sending this.
pub const ERR_PROTOCOL: u16 = 1;
/// Header carried an unsupported protocol version; connection closed.
pub const ERR_VERSION: u16 = 2;
/// Lease rejected (no capacity / unknown task / admission control).
pub const ERR_LEASE: u16 = 3;
/// Frame referenced a session id this connection never leased.
pub const ERR_SESSION: u16 = 4;
/// Submit carried no acceptable slot/action pairs; nothing was buffered.
pub const ERR_SUBMIT: u16 = 5;
/// The shard backing the session failed; the session is gone.
pub const ERR_SHARD: u16 = 6;
/// Overload shed: the request was declined, not failed — retry later.
/// The message may carry a hint via [`with_retry_after`] /
/// [`retry_after_ms`]. Sent for admission declines, submit-inbox
/// floods (the submit is dropped, the connection and lease survive),
/// failed resumes, and parked-slot exhaustion.
pub const ERR_RETRY_AFTER: u16 = 7;
/// Farewell before a slow-reader disconnect: the client's socket
/// backlogged past the outbox bound. The lease is parked (resumable)
/// when a park TTL is configured.
pub const ERR_SLOW_READER: u16 = 8;
/// The shard backing the session panicked and is quarantined; the
/// lease is gone, but the shard may be restarted — the message carries
/// a [`with_retry_after`] hint for when to try a fresh lease.
pub const ERR_SHARD_DOWN: u16 = 9;

/// Prefix `msg` with a machine-readable retry-after hint that
/// [`retry_after_ms`] recovers. Kept inside the message string so the
/// `ERROR` frame layout (and protocol version) is unchanged.
pub fn with_retry_after(ms: u64, msg: &str) -> String {
    format!("retry_after_ms={ms}; {msg}")
}

/// Parse the hint written by [`with_retry_after`], if present.
pub fn retry_after_ms(msg: &str) -> Option<u64> {
    let rest = msg.strip_prefix("retry_after_ms=")?;
    let end = rest.find(';')?;
    rest[..end].trim().parse().ok()
}

/// A frame-grammar violation. The server answers with an
/// [`ERR_PROTOCOL`]/[`ERR_VERSION`] error frame (best effort) and closes
/// the connection; co-tenant sessions on other connections are untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// First two header bytes were not [`MAGIC`] (mid-stream garbage).
    BadMagic,
    /// Unsupported protocol version.
    BadVersion(u8),
    /// Length field exceeds [`MAX_FRAME`].
    Oversized(u32),
    /// Unknown frame type byte.
    UnknownType(u8),
    /// Stream or payload ended before the announced length.
    Truncated,
    /// Payload bytes do not decode as the announced frame type.
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic => write!(f, "bad frame magic"),
            WireError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (want {VERSION})")
            }
            WireError::Oversized(n) => {
                write!(f, "frame length {n} exceeds the {MAX_FRAME}-byte cap")
            }
            WireError::UnknownType(t) => write!(f, "unknown frame type {t}"),
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl WireError {
    /// The error-frame code a server reports this violation as.
    pub fn code(&self) -> u16 {
        match self {
            WireError::BadVersion(_) => ERR_VERSION,
            _ => ERR_PROTOCOL,
        }
    }
}

/// Why reading one frame off a stream stopped.
#[derive(Debug)]
pub enum ReadError {
    /// Clean end of stream on a frame boundary.
    Eof,
    /// Transport error (timeouts, resets).
    Io(std::io::Error),
    /// The bytes violate the frame grammar (includes mid-frame EOF).
    Wire(WireError),
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::Eof => write!(f, "connection closed"),
            ReadError::Io(e) => write!(f, "transport error: {e}"),
            ReadError::Wire(e) => write!(f, "protocol error: {e}"),
        }
    }
}

/// The SoA step-view arrays of a [`Frame::Step`], same shapes as
/// `serve::SessionView` restricted to the session's `n` leased slots
/// (`obs` is `n * obs_floats`, `goal` is `n * 3`, the rest are `n`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StepFrame {
    pub obs: Vec<f32>,
    pub goal: Vec<f32>,
    pub rewards: Vec<f32>,
    pub dones: Vec<bool>,
    pub successes: Vec<bool>,
    pub spl: Vec<f32>,
    pub scores: Vec<f32>,
}

/// One protocol frame (see module docs for the grammar).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server, first frame on every connection.
    Hello,
    /// Server → client, answers `Hello`.
    Welcome { shards: u32 },
    /// Client → server: lease `n_envs` slots of `task`. `req` correlates
    /// the `Grant`/`Error` answer when leases are pipelined.
    Lease { req: u64, task: Task, n_envs: u32 },
    /// Server → client: the lease was granted. `slots` are the
    /// shard-absolute env slot indices, in view order; `session` names
    /// the lease in every later frame. `token` is the opaque resume
    /// token a later [`Frame::Resume`] must present to reattach to this
    /// lease after a disconnect. An initial `Step` with the current
    /// observations follows immediately.
    Grant {
        req: u64,
        session: u64,
        token: u64,
        task: Task,
        obs_floats: u32,
        slots: Vec<u32>,
    },
    /// Client → server: buffer `action` for shard-absolute slot index
    /// `slot`, for each pair. Bad indices are skipped server-side and
    /// counted in the shard's `bad_submits` — they never panic the shard.
    Submit { session: u64, pairs: Vec<(u32, u8)> },
    /// Server → client: the session's slice of one completed batch step.
    /// Exactly one per accepted `Submit`, plus one right after `Grant`.
    Step {
        session: u64,
        step: u64,
        obs_floats: u32,
        view: StepFrame,
    },
    /// Client → server: release the lease.
    Detach { session: u64 },
    /// Server → client: the lease is released (answers `Detach`).
    Detached { session: u64 },
    /// Server → client: request- or connection-level failure. `re` is
    /// the `req` or `session` it refers to (0 = the connection itself).
    Error { re: u64, code: u16, msg: String },
    /// Client → server: lease `n_envs` slots of `task` *plus* the named
    /// policy `variant`, server-driven (a policy tenant). Answered like
    /// `Lease` — `Grant` + initial `Step` — but afterwards the server
    /// streams `Traj` frames instead of waiting for `Submit`s.
    /// `greedy = false` samples actions on a per-tenant RNG seeded with
    /// `seed`; the variant name is bounded utf-8 (≤ 256 bytes).
    LeasePolicy {
        req: u64,
        task: Task,
        n_envs: u32,
        greedy: bool,
        seed: u64,
        variant: String,
    },
    /// Client → server: drive the tenant session for `steps` more steps
    /// (goals accumulate; see `TenantControl::set_goal`).
    Goal { session: u64, steps: u32 },
    /// Server → client: one server-driven step of a policy tenancy —
    /// the actions the policy chose for the leased slots (`actions`,
    /// one per slot in view order) plus the resulting step slice.
    Traj {
        session: u64,
        step: u64,
        obs_floats: u32,
        actions: Vec<u8>,
        view: StepFrame,
    },
    /// Client → server: request a registry snapshot. `req` correlates
    /// the [`Frame::StatsReply`] when requests are pipelined.
    Stats { req: u64 },
    /// Server → client: answers `Stats` with the snapshot `version`
    /// (see `obs::SNAPSHOT_VERSION`) and the Prometheus text exposition
    /// of the registry — the same bytes `GET /metrics` would serve at
    /// that instant.
    StatsReply {
        req: u64,
        version: u32,
        text: String,
    },
    /// Client → server: trigger a manual flight-recorder incident
    /// bundle. `req` correlates the [`Frame::DumpReply`].
    Dump { req: u64 },
    /// Server → client: answers `Dump`. With `ok`, `msg` is the
    /// server-side bundle directory path; without, the reason the dump
    /// was declined (most commonly: no `--dump-dir`, recorder unarmed).
    DumpReply { req: u64, ok: bool, msg: String },
    /// Client → server: reattach to a parked lease after a disconnect.
    /// `session`/`token` must match a prior [`Frame::Grant`];
    /// `delivered` is the last step sequence number the client fully
    /// received, so the server can replay or discard the one in-flight
    /// step. Answered by [`Frame::Resumed`] (then the step stream
    /// continues) or an [`ERR_RETRY_AFTER`] error when the park
    /// expired or the token does not match.
    Resume {
        req: u64,
        session: u64,
        token: u64,
        delivered: u64,
    },
    /// Server → client: the lease is reattached. `applied` is how many
    /// submits the server has fully applied; when `applied` is ahead of
    /// the client's `delivered`, the step the client missed is replayed
    /// immediately after this frame.
    Resumed { req: u64, session: u64, applied: u64 },
}

impl Frame {
    fn ftype(&self) -> u8 {
        match self {
            Frame::Hello => FT_HELLO,
            Frame::Welcome { .. } => FT_WELCOME,
            Frame::Lease { .. } => FT_LEASE,
            Frame::Grant { .. } => FT_GRANT,
            Frame::Submit { .. } => FT_SUBMIT,
            Frame::Step { .. } => FT_STEP,
            Frame::Detach { .. } => FT_DETACH,
            Frame::Detached { .. } => FT_DETACHED,
            Frame::Error { .. } => FT_ERROR,
            Frame::LeasePolicy { .. } => FT_LEASE_POLICY,
            Frame::Goal { .. } => FT_GOAL,
            Frame::Traj { .. } => FT_TRAJ,
            Frame::Stats { .. } => FT_STATS,
            Frame::StatsReply { .. } => FT_STATS_REPLY,
            Frame::Dump { .. } => FT_DUMP,
            Frame::DumpReply { .. } => FT_DUMP_REPLY,
            Frame::Resume { .. } => FT_RESUME,
            Frame::Resumed { .. } => FT_RESUMED,
        }
    }
}

fn task_to_wire(t: Task) -> u8 {
    match t {
        Task::PointNav => 0,
        Task::Flee => 1,
        Task::Explore => 2,
    }
}

fn task_from_wire(b: u8) -> Result<Task, WireError> {
    match b {
        0 => Ok(Task::PointNav),
        1 => Ok(Task::Flee),
        2 => Ok(Task::Explore),
        _ => Err(WireError::Malformed("unknown task")),
    }
}

// ---- encoding ---------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}
fn put_bools(out: &mut Vec<u8>, xs: &[bool]) {
    out.extend(xs.iter().map(|&b| b as u8));
}

fn begin_frame(out: &mut Vec<u8>, ftype: u8) {
    out.clear();
    put_u16(out, MAGIC);
    out.push(VERSION);
    out.push(ftype);
    put_u32(out, 0); // length, patched by finish_frame
}

fn finish_frame(out: &mut Vec<u8>) {
    let len = (out.len() - HEADER_LEN) as u32;
    out[4..8].copy_from_slice(&len.to_le_bytes());
}

/// Borrowed step-view arrays for [`encode_step`] — the server's send
/// path serializes straight from the session's slices instead of
/// cloning them into an owned [`StepFrame`] first (the observation
/// megaframe dominates wire traffic, so the extra copy would double
/// the hot path's memory traffic). `StepFrame` remains the decode type.
#[derive(Clone, Copy)]
pub struct StepRef<'a> {
    pub obs: &'a [f32],
    pub goal: &'a [f32],
    pub rewards: &'a [f32],
    pub dones: &'a [bool],
    pub successes: &'a [bool],
    pub spl: &'a [f32],
    pub scores: &'a [f32],
}

fn put_step_body(out: &mut Vec<u8>, session: u64, step: u64, obs_floats: u32, v: StepRef<'_>) {
    put_u64(out, session);
    put_u64(out, step);
    put_u32(out, v.rewards.len() as u32);
    put_u32(out, obs_floats);
    put_f32s(out, v.obs);
    put_f32s(out, v.goal);
    put_f32s(out, v.rewards);
    put_bools(out, v.dones);
    put_bools(out, v.successes);
    put_f32s(out, v.spl);
    put_f32s(out, v.scores);
}

/// Serialize a `STEP` frame directly from borrowed slices into `out`
/// (replacing its contents). Byte-identical to encoding the equivalent
/// [`Frame::Step`] — asserted in the unit tests.
pub fn encode_step(out: &mut Vec<u8>, session: u64, step: u64, obs_floats: u32, v: StepRef<'_>) {
    begin_frame(out, FT_STEP);
    put_step_body(out, session, step, obs_floats, v);
    finish_frame(out);
}

fn put_traj_body(
    out: &mut Vec<u8>,
    session: u64,
    step: u64,
    obs_floats: u32,
    actions: &[u8],
    v: StepRef<'_>,
) {
    put_u64(out, session);
    put_u64(out, step);
    put_u32(out, actions.len() as u32);
    put_u32(out, obs_floats);
    out.extend_from_slice(actions);
    put_f32s(out, v.obs);
    put_f32s(out, v.goal);
    put_f32s(out, v.rewards);
    put_bools(out, v.dones);
    put_bools(out, v.successes);
    put_f32s(out, v.spl);
    put_f32s(out, v.scores);
}

/// Serialize a `TRAJ` frame directly from borrowed slices into `out`
/// (replacing its contents) — the agent pump's zero-copy send path,
/// mirroring [`encode_step`]. Byte-identical to encoding the equivalent
/// [`Frame::Traj`] — asserted in the unit tests.
pub fn encode_traj(
    out: &mut Vec<u8>,
    session: u64,
    step: u64,
    obs_floats: u32,
    actions: &[u8],
    v: StepRef<'_>,
) {
    begin_frame(out, FT_TRAJ);
    put_traj_body(out, session, step, obs_floats, actions, v);
    finish_frame(out);
}

/// Serialize `f` (header + payload) into `out`, replacing its contents.
pub fn encode(f: &Frame, out: &mut Vec<u8>) {
    begin_frame(out, f.ftype());
    match f {
        Frame::Hello => {}
        Frame::Welcome { shards } => put_u32(out, *shards),
        Frame::Lease { req, task, n_envs } => {
            put_u64(out, *req);
            out.push(task_to_wire(*task));
            put_u32(out, *n_envs);
        }
        Frame::Grant {
            req,
            session,
            token,
            task,
            obs_floats,
            slots,
        } => {
            put_u64(out, *req);
            put_u64(out, *session);
            put_u64(out, *token);
            out.push(task_to_wire(*task));
            put_u32(out, *obs_floats);
            put_u32(out, slots.len() as u32);
            for &s in slots {
                put_u32(out, s);
            }
        }
        Frame::Submit { session, pairs } => {
            put_u64(out, *session);
            put_u32(out, pairs.len() as u32);
            for &(slot, action) in pairs {
                put_u32(out, slot);
                out.push(action);
            }
        }
        Frame::Step {
            session,
            step,
            obs_floats,
            view,
        } => {
            let v = StepRef {
                obs: &view.obs,
                goal: &view.goal,
                rewards: &view.rewards,
                dones: &view.dones,
                successes: &view.successes,
                spl: &view.spl,
                scores: &view.scores,
            };
            put_step_body(out, *session, *step, *obs_floats, v);
        }
        Frame::Detach { session } => put_u64(out, *session),
        Frame::Detached { session } => put_u64(out, *session),
        Frame::Error { re, code, msg } => {
            put_u64(out, *re);
            put_u16(out, *code);
            put_u32(out, msg.len() as u32);
            out.extend_from_slice(msg.as_bytes());
        }
        Frame::LeasePolicy {
            req,
            task,
            n_envs,
            greedy,
            seed,
            variant,
        } => {
            put_u64(out, *req);
            out.push(task_to_wire(*task));
            put_u32(out, *n_envs);
            out.push(*greedy as u8);
            put_u64(out, *seed);
            put_u32(out, variant.len() as u32);
            out.extend_from_slice(variant.as_bytes());
        }
        Frame::Goal { session, steps } => {
            put_u64(out, *session);
            put_u32(out, *steps);
        }
        Frame::Traj {
            session,
            step,
            obs_floats,
            actions,
            view,
        } => {
            let v = StepRef {
                obs: &view.obs,
                goal: &view.goal,
                rewards: &view.rewards,
                dones: &view.dones,
                successes: &view.successes,
                spl: &view.spl,
                scores: &view.scores,
            };
            put_traj_body(out, *session, *step, *obs_floats, actions, v);
        }
        Frame::Stats { req } => put_u64(out, *req),
        Frame::StatsReply { req, version, text } => {
            put_u64(out, *req);
            put_u32(out, *version);
            put_u32(out, text.len() as u32);
            out.extend_from_slice(text.as_bytes());
        }
        Frame::Dump { req } => put_u64(out, *req),
        Frame::DumpReply { req, ok, msg } => {
            put_u64(out, *req);
            out.push(*ok as u8);
            put_u32(out, msg.len() as u32);
            out.extend_from_slice(msg.as_bytes());
        }
        Frame::Resume {
            req,
            session,
            token,
            delivered,
        } => {
            put_u64(out, *req);
            put_u64(out, *session);
            put_u64(out, *token);
            put_u64(out, *delivered);
        }
        Frame::Resumed {
            req,
            session,
            applied,
        } => {
            put_u64(out, *req);
            put_u64(out, *session);
            put_u64(out, *applied);
        }
    }
    finish_frame(out);
}

// ---- decoding ---------------------------------------------------------

/// A validated frame header: the payload is `len` bytes of `ftype`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub ftype: u8,
    pub len: usize,
}

/// Validate the fixed 8-byte header. All hostile-length/type/version
/// checks happen here, before any payload allocation.
pub fn decode_header(b: &[u8; HEADER_LEN]) -> Result<Header, WireError> {
    let magic = u16::from_le_bytes([b[0], b[1]]);
    if magic != MAGIC {
        return Err(WireError::BadMagic);
    }
    if b[2] != VERSION {
        return Err(WireError::BadVersion(b[2]));
    }
    let ftype = b[3];
    if !(FT_HELLO..=FT_RESUMED).contains(&ftype) {
        return Err(WireError::UnknownType(ftype));
    }
    let len = u32::from_le_bytes([b[4], b[5], b[6], b[7]]);
    if len as usize > MAX_FRAME {
        return Err(WireError::Oversized(len));
    }
    Ok(Header {
        ftype,
        len: len as usize,
    })
}

/// Bounds-checked payload reader: every `take` is validated against the
/// remaining bytes, so count fields from the wire cannot over-read.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: u64) -> Result<&'a [u8], WireError> {
        let rem = (self.b.len() - self.pos) as u64;
        if n > rem {
            return Err(WireError::Truncated);
        }
        let n = n as usize; // n <= rem <= MAX_FRAME, fits usize
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32s(&mut self, n: u64) -> Result<Vec<f32>, WireError> {
        // checked: n can be a product of two wire u32s, so n*4 could wrap
        let bytes = n.checked_mul(4).ok_or(WireError::Truncated)?;
        let b = self.take(bytes)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn bools(&mut self, n: u64) -> Result<Vec<bool>, WireError> {
        Ok(self.take(n)?.iter().map(|&b| b != 0).collect())
    }

    fn done(&self) -> Result<(), WireError> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(WireError::Malformed("trailing bytes"))
        }
    }
}

/// Decode a payload whose header announced `ftype`.
pub fn decode_payload(ftype: u8, payload: &[u8]) -> Result<Frame, WireError> {
    let mut r = Reader { b: payload, pos: 0 };
    let f = match ftype {
        FT_HELLO => Frame::Hello,
        FT_WELCOME => Frame::Welcome { shards: r.u32()? },
        FT_LEASE => Frame::Lease {
            req: r.u64()?,
            task: task_from_wire(r.u8()?)?,
            n_envs: r.u32()?,
        },
        FT_GRANT => {
            let req = r.u64()?;
            let session = r.u64()?;
            let token = r.u64()?;
            let task = task_from_wire(r.u8()?)?;
            let obs_floats = r.u32()?;
            let n = r.u32()? as u64;
            let bytes = r.take(n * 4)?;
            let slots = bytes
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Frame::Grant {
                req,
                session,
                token,
                task,
                obs_floats,
                slots,
            }
        }
        FT_SUBMIT => {
            let session = r.u64()?;
            let n = r.u32()? as u64;
            let bytes = r.take(n * 5)?;
            let pairs = bytes
                .chunks_exact(5)
                .map(|c| (u32::from_le_bytes([c[0], c[1], c[2], c[3]]), c[4]))
                .collect();
            Frame::Submit { session, pairs }
        }
        FT_STEP => {
            let session = r.u64()?;
            let step = r.u64()?;
            let n = r.u32()? as u64;
            let obs_floats = r.u32()?;
            let view = StepFrame {
                obs: r.f32s(n * obs_floats as u64)?,
                goal: r.f32s(n * 3)?,
                rewards: r.f32s(n)?,
                dones: r.bools(n)?,
                successes: r.bools(n)?,
                spl: r.f32s(n)?,
                scores: r.f32s(n)?,
            };
            Frame::Step {
                session,
                step,
                obs_floats,
                view,
            }
        }
        FT_DETACH => Frame::Detach { session: r.u64()? },
        FT_DETACHED => Frame::Detached { session: r.u64()? },
        FT_ERROR => {
            let re = r.u64()?;
            let code = r.u16()?;
            let len = r.u32()? as u64;
            let msg = String::from_utf8_lossy(r.take(len)?).into_owned();
            Frame::Error { re, code, msg }
        }
        FT_LEASE_POLICY => {
            let req = r.u64()?;
            let task = task_from_wire(r.u8()?)?;
            let n_envs = r.u32()?;
            let greedy = r.u8()? != 0;
            let seed = r.u64()?;
            let vlen = r.u32()? as u64;
            if vlen > MAX_VARIANT_NAME as u64 {
                return Err(WireError::Malformed("variant name too long"));
            }
            let variant = std::str::from_utf8(r.take(vlen)?)
                .map_err(|_| WireError::Malformed("variant name not utf-8"))?
                .to_owned();
            Frame::LeasePolicy {
                req,
                task,
                n_envs,
                greedy,
                seed,
                variant,
            }
        }
        FT_GOAL => Frame::Goal {
            session: r.u64()?,
            steps: r.u32()?,
        },
        FT_TRAJ => {
            let session = r.u64()?;
            let step = r.u64()?;
            let n = r.u32()? as u64;
            let obs_floats = r.u32()?;
            let actions = r.take(n)?.to_vec();
            let view = StepFrame {
                obs: r.f32s(n * obs_floats as u64)?,
                goal: r.f32s(n * 3)?,
                rewards: r.f32s(n)?,
                dones: r.bools(n)?,
                successes: r.bools(n)?,
                spl: r.f32s(n)?,
                scores: r.f32s(n)?,
            };
            Frame::Traj {
                session,
                step,
                obs_floats,
                actions,
                view,
            }
        }
        FT_STATS => Frame::Stats { req: r.u64()? },
        FT_STATS_REPLY => {
            let req = r.u64()?;
            let version = r.u32()?;
            let len = r.u32()? as u64;
            let text = String::from_utf8_lossy(r.take(len)?).into_owned();
            Frame::StatsReply { req, version, text }
        }
        FT_DUMP => Frame::Dump { req: r.u64()? },
        FT_DUMP_REPLY => {
            let req = r.u64()?;
            let ok = r.u8()? != 0;
            let len = r.u32()? as u64;
            let msg = String::from_utf8_lossy(r.take(len)?).into_owned();
            Frame::DumpReply { req, ok, msg }
        }
        FT_RESUME => Frame::Resume {
            req: r.u64()?,
            session: r.u64()?,
            token: r.u64()?,
            delivered: r.u64()?,
        },
        FT_RESUMED => Frame::Resumed {
            req: r.u64()?,
            session: r.u64()?,
            applied: r.u64()?,
        },
        other => return Err(WireError::UnknownType(other)),
    };
    r.done()?;
    Ok(f)
}

/// Most envs one wire session may lease. Derived from the frame caps:
/// a session's `SUBMIT` (`12 + 5n` ≤ [`SUBMIT_CAP`]) and `GRANT`
/// (`33 + 4n` ≤ [`GRANT_CAP`]) must stay encodable, and its `STEP`
/// view must fit [`MAX_FRAME`] (also obs-size dependent — the server
/// checks that at lease time). Both ends enforce this so an over-sized
/// lease fails diagnosably instead of bricking the session on its
/// first submit.
pub const MAX_SESSION_ENVS: usize = 8192;

/// Generous bound for the variable-length client→server `SUBMIT`
/// payload (`12 + 5n` bytes — 64 KiB covers >13k slot/action pairs).
const SUBMIT_CAP: usize = 64 << 10;
/// Bound for the server→client `GRANT` payload (`33 + 4n` bytes).
const GRANT_CAP: usize = 64 << 10;
/// Bound for an `ERROR` payload (`14 + msg` bytes).
const ERROR_CAP: usize = 16 << 10;
/// Longest policy-variant name a `LEASE_POLICY` may carry.
pub const MAX_VARIANT_NAME: usize = 256;
/// Bound for the client→server `LEASE_POLICY` payload
/// (`26 + vlen` bytes with `vlen` ≤ [`MAX_VARIANT_NAME`]).
const LEASE_POLICY_CAP: usize = 26 + MAX_VARIANT_NAME;
/// Bound for the server→client `STATS_REPLY` payload (`16 + text`
/// bytes). A registry exposition is a few KiB per shard; 1 MiB leaves
/// room for hundreds of shards without letting a hostile server pin
/// [`MAX_FRAME`]-sized allocations on a stats client.
pub const STATS_CAP: usize = 1 << 20;
/// Bound for the server→client `DUMP_REPLY` payload (`13 + msg` bytes —
/// a bundle path or a short decline reason).
pub const DUMP_REPLY_CAP: usize = 16 << 10;

/// Largest legal payload for `ftype` in one direction (`from_client` =
/// the reader is a server). `None` means the type never flows that way.
/// Checked against the header *before* the payload buffer is allocated:
/// every client→server frame is small, so an unauthenticated peer
/// cannot pin [`MAX_FRAME`]-sized allocations with an 8-byte header —
/// only the server→client `STEP` direction legitimately carries
/// megabytes (the observation megaframe).
pub fn payload_cap(ftype: u8, from_client: bool) -> Option<usize> {
    match (ftype, from_client) {
        (FT_HELLO, true) => Some(0),
        (FT_LEASE, true) => Some(13),
        (FT_SUBMIT, true) => Some(SUBMIT_CAP),
        (FT_DETACH, true) => Some(8),
        (FT_LEASE_POLICY, true) => Some(LEASE_POLICY_CAP),
        (FT_GOAL, true) => Some(12),
        (FT_STATS, true) => Some(8),
        (FT_DUMP, true) => Some(8),
        (FT_WELCOME, false) => Some(4),
        (FT_GRANT, false) => Some(GRANT_CAP),
        (FT_STEP, false) => Some(MAX_FRAME),
        (FT_DETACHED, false) => Some(8),
        (FT_ERROR, false) => Some(ERROR_CAP),
        (FT_TRAJ, false) => Some(MAX_FRAME),
        (FT_STATS_REPLY, false) => Some(STATS_CAP),
        (FT_DUMP_REPLY, false) => Some(DUMP_REPLY_CAP),
        (FT_RESUME, true) => Some(32),
        (FT_RESUMED, false) => Some(24),
        _ => None,
    }
}

/// Read exactly one frame off a blocking stream. Distinguishes a clean
/// close on a frame boundary ([`ReadError::Eof`]) from a mid-frame close
/// ([`WireError::Truncated`]) so the server can count the latter as a
/// protocol violation. Applies only the generic [`MAX_FRAME`] bound —
/// endpoints should prefer [`read_frame_dir`], which also enforces the
/// per-type, per-direction payload caps.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, ReadError> {
    read_frame_capped(r, |_| Some(MAX_FRAME))
}

/// [`read_frame`] with the direction-aware payload caps of
/// [`payload_cap`]: wrong-direction frames and oversized-for-their-type
/// length fields are rejected from the header alone, allocation-free.
pub fn read_frame_dir(r: &mut impl Read, from_client: bool) -> Result<Frame, ReadError> {
    read_frame_capped(r, |ftype| payload_cap(ftype, from_client))
}

fn read_frame_capped(
    r: &mut impl Read,
    cap: impl Fn(u8) -> Option<usize>,
) -> Result<Frame, ReadError> {
    let mut hdr = [0u8; HEADER_LEN];
    read_fully(r, &mut hdr, true)?;
    let h = decode_header(&hdr).map_err(ReadError::Wire)?;
    match cap(h.ftype) {
        None => {
            return Err(ReadError::Wire(WireError::Malformed(
                "frame type not allowed in this direction",
            )))
        }
        Some(limit) if h.len > limit => {
            return Err(ReadError::Wire(WireError::Oversized(h.len as u32)))
        }
        Some(_) => {}
    }
    let mut payload = vec![0u8; h.len];
    read_fully(r, &mut payload, false)?;
    decode_payload(h.ftype, &payload).map_err(ReadError::Wire)
}

/// Fill `buf` from the stream. `at_boundary` marks the read as starting
/// on a frame boundary, where 0 bytes is a clean close rather than a
/// truncated frame.
fn read_fully(r: &mut impl Read, buf: &mut [u8], at_boundary: bool) -> Result<(), ReadError> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(if at_boundary && got == 0 {
                    ReadError::Eof
                } else {
                    ReadError::Wire(WireError::Truncated)
                });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(ReadError::Io(e)),
        }
    }
    Ok(())
}

/// Serialize and write one frame.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(HEADER_LEN + 64);
    encode(f, &mut buf);
    w.write_all(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        encode(&f, &mut buf);
        let mut hdr = [0u8; HEADER_LEN];
        hdr.copy_from_slice(&buf[..HEADER_LEN]);
        let h = decode_header(&hdr).unwrap();
        assert_eq!(h.len, buf.len() - HEADER_LEN, "length prefix");
        let out = decode_payload(h.ftype, &buf[HEADER_LEN..]).unwrap();
        assert_eq!(out, f);
    }

    #[test]
    fn every_frame_round_trips() {
        roundtrip(Frame::Hello);
        roundtrip(Frame::Welcome { shards: 3 });
        roundtrip(Frame::Lease {
            req: 7,
            task: Task::Flee,
            n_envs: 16,
        });
        roundtrip(Frame::Grant {
            req: 7,
            session: 42,
            token: 0x1234_5678_9ABC_DEF0,
            task: Task::PointNav,
            obs_floats: 400,
            slots: vec![0, 1, 5, 9],
        });
        roundtrip(Frame::Submit {
            session: 42,
            pairs: vec![(0, 1), (5, 3), (u32::MAX, 0)],
        });
        roundtrip(Frame::Step {
            session: 42,
            step: 99,
            obs_floats: 2,
            view: StepFrame {
                obs: vec![0.25, -1.5, f32::MIN_POSITIVE, 3.0],
                goal: vec![1.0; 6],
                rewards: vec![-0.01, 2.5],
                dones: vec![true, false],
                successes: vec![false, true],
                spl: vec![0.0, 0.9],
                scores: vec![1.0, 0.0],
            },
        });
        roundtrip(Frame::Detach { session: 42 });
        roundtrip(Frame::Detached { session: 42 });
        roundtrip(Frame::Error {
            re: 42,
            code: ERR_LEASE,
            msg: "no capacity".into(),
        });
        roundtrip(Frame::LeasePolicy {
            req: 3,
            task: Task::PointNav,
            n_envs: 4,
            greedy: true,
            seed: 0xDEAD_BEEF,
            variant: "test".into(),
        });
        roundtrip(Frame::Goal {
            session: 42,
            steps: 128,
        });
        roundtrip(Frame::Stats { req: 5 });
        roundtrip(Frame::Dump { req: 9 });
        roundtrip(Frame::DumpReply {
            req: 9,
            ok: true,
            msg: "/tmp/bundles/incident-00001-manual".into(),
        });
        roundtrip(Frame::DumpReply {
            req: 10,
            ok: false,
            msg: "flight recorder not armed".into(),
        });
        roundtrip(Frame::StatsReply {
            req: 5,
            version: 1,
            text: "# bps registry snapshot v1\nserve_shard_steps{shard=\"0\"} 7\n".into(),
        });
        roundtrip(Frame::Traj {
            session: 42,
            step: 7,
            obs_floats: 2,
            actions: vec![0, 3],
            view: StepFrame {
                obs: vec![0.25, -1.5, f32::MIN_POSITIVE, 3.0],
                goal: vec![1.0; 6],
                rewards: vec![-0.01, 2.5],
                dones: vec![true, false],
                successes: vec![false, true],
                spl: vec![0.0, 0.9],
                scores: vec![1.0, 0.0],
            },
        });
        roundtrip(Frame::Resume {
            req: 11,
            session: 42,
            token: u64::MAX,
            delivered: 99,
        });
        roundtrip(Frame::Resumed {
            req: 11,
            session: 42,
            applied: 100,
        });
    }

    /// Resume frames are asymmetric and fixed-size; the retry-after
    /// hint survives its message-string round trip.
    #[test]
    fn resume_frames_and_retry_after_hint() {
        assert_eq!(payload_cap(FT_RESUME, true), Some(32));
        assert_eq!(payload_cap(FT_RESUME, false), None);
        assert_eq!(payload_cap(FT_RESUMED, false), Some(24));
        assert_eq!(payload_cap(FT_RESUMED, true), None);
        let msg = with_retry_after(250, "shard 0 quarantined");
        assert_eq!(retry_after_ms(&msg), Some(250));
        assert!(msg.contains("shard 0 quarantined"));
        assert_eq!(retry_after_ms("plain failure"), None);
        assert_eq!(retry_after_ms("retry_after_ms=oops; x"), None);
    }

    /// The zero-copy server send path must emit exactly the bytes the
    /// general encoder would.
    #[test]
    fn encode_step_matches_frame_encode() {
        let view = StepFrame {
            obs: vec![0.5, -2.0, 3.25, 0.0],
            goal: vec![1.0; 6],
            rewards: vec![0.1, -0.2],
            dones: vec![true, false],
            successes: vec![false, true],
            spl: vec![0.9, 0.0],
            scores: vec![0.0, 7.5],
        };
        let f = Frame::Step {
            session: 11,
            step: 42,
            obs_floats: 2,
            view: view.clone(),
        };
        let mut via_frame = Vec::new();
        encode(&f, &mut via_frame);
        let mut direct = Vec::new();
        encode_step(
            &mut direct,
            11,
            42,
            2,
            StepRef {
                obs: &view.obs,
                goal: &view.goal,
                rewards: &view.rewards,
                dones: &view.dones,
                successes: &view.successes,
                spl: &view.spl,
                scores: &view.scores,
            },
        );
        assert_eq!(via_frame, direct);
    }

    /// Same guarantee for the agent pump's zero-copy `TRAJ` path.
    #[test]
    fn encode_traj_matches_frame_encode() {
        let view = StepFrame {
            obs: vec![0.5, -2.0, 3.25, 0.0],
            goal: vec![1.0; 6],
            rewards: vec![0.1, -0.2],
            dones: vec![true, false],
            successes: vec![false, true],
            spl: vec![0.9, 0.0],
            scores: vec![0.0, 7.5],
        };
        let actions = vec![1u8, 2];
        let f = Frame::Traj {
            session: 11,
            step: 42,
            obs_floats: 2,
            actions: actions.clone(),
            view: view.clone(),
        };
        let mut via_frame = Vec::new();
        encode(&f, &mut via_frame);
        let mut direct = Vec::new();
        encode_traj(
            &mut direct,
            11,
            42,
            2,
            &actions,
            StepRef {
                obs: &view.obs,
                goal: &view.goal,
                rewards: &view.rewards,
                dones: &view.dones,
                successes: &view.successes,
                spl: &view.spl,
                scores: &view.scores,
            },
        );
        assert_eq!(via_frame, direct);
    }

    #[test]
    fn hostile_lease_policy_payloads_rejected() {
        let mut buf = Vec::new();
        encode(
            &Frame::LeasePolicy {
                req: 1,
                task: Task::PointNav,
                n_envs: 4,
                greedy: true,
                seed: 0,
                variant: "ab".into(),
            },
            &mut buf,
        );
        // variant length field larger than the cap
        let mut payload = buf[HEADER_LEN..].to_vec();
        payload[22..26].copy_from_slice(&(MAX_VARIANT_NAME as u32 + 1).to_le_bytes());
        assert_eq!(
            decode_payload(FT_LEASE_POLICY, &payload),
            Err(WireError::Malformed("variant name too long"))
        );
        // variant length field overruns the actual payload
        let mut payload = buf[HEADER_LEN..].to_vec();
        payload[22..26].copy_from_slice(&200u32.to_le_bytes());
        assert_eq!(
            decode_payload(FT_LEASE_POLICY, &payload),
            Err(WireError::Truncated)
        );
        // non-utf8 variant bytes
        let mut payload = buf[HEADER_LEN..].to_vec();
        payload[26] = 0xFF;
        payload[27] = 0xFE;
        assert_eq!(
            decode_payload(FT_LEASE_POLICY, &payload),
            Err(WireError::Malformed("variant name not utf-8"))
        );
        // and the per-type cap bounds what a client may even announce
        assert_eq!(payload_cap(FT_LEASE_POLICY, true), Some(26 + MAX_VARIANT_NAME));
        assert_eq!(payload_cap(FT_GOAL, true), Some(12));
        // tenant frames never flow the other way
        assert_eq!(payload_cap(FT_TRAJ, true), None);
        assert_eq!(payload_cap(FT_LEASE_POLICY, false), None);
        assert_eq!(payload_cap(FT_GOAL, false), None);
    }

    #[test]
    fn header_range_covers_tenant_and_stats_frames() {
        let m = MAGIC.to_le_bytes();
        for ft in [
            FT_LEASE_POLICY,
            FT_GOAL,
            FT_TRAJ,
            FT_STATS,
            FT_STATS_REPLY,
            FT_DUMP,
            FT_DUMP_REPLY,
            FT_RESUME,
            FT_RESUMED,
        ] {
            let h = [m[0], m[1], VERSION, ft, 0, 0, 0, 0];
            assert!(decode_header(&h).is_ok(), "type {ft} must validate");
        }
        let h = [m[0], m[1], VERSION, FT_RESUMED + 1, 0, 0, 0, 0];
        assert_eq!(
            decode_header(&h),
            Err(WireError::UnknownType(FT_RESUMED + 1))
        );
        // dump frames are asymmetric like stats frames
        assert_eq!(payload_cap(FT_DUMP, true), Some(8));
        assert_eq!(payload_cap(FT_DUMP, false), None);
        assert_eq!(payload_cap(FT_DUMP_REPLY, false), Some(DUMP_REPLY_CAP));
        assert_eq!(payload_cap(FT_DUMP_REPLY, true), None);
    }

    /// Stats frames are asymmetric: the request is a tiny fixed-size
    /// client frame, the reply is server-only and capped well below
    /// [`MAX_FRAME`].
    #[test]
    fn stats_frames_direction_and_caps() {
        assert_eq!(payload_cap(FT_STATS, true), Some(8));
        assert_eq!(payload_cap(FT_STATS, false), None);
        assert_eq!(payload_cap(FT_STATS_REPLY, false), Some(STATS_CAP));
        assert_eq!(payload_cap(FT_STATS_REPLY, true), None);
        // a reply whose text length field overruns the payload
        let mut buf = Vec::new();
        encode(
            &Frame::StatsReply {
                req: 1,
                version: 1,
                text: "ok".into(),
            },
            &mut buf,
        );
        let mut payload = buf[HEADER_LEN..].to_vec();
        payload[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(
            decode_payload(FT_STATS_REPLY, &payload),
            Err(WireError::Truncated)
        );
    }

    #[test]
    fn observation_bits_survive_the_wire() {
        // exact IEEE bit patterns, including negative zero and subnormals
        let xs = [0.0f32, -0.0, 1.0e-42, f32::MAX, -f32::MIN_POSITIVE];
        let f = Frame::Step {
            session: 1,
            step: 1,
            obs_floats: xs.len() as u32,
            view: StepFrame {
                obs: xs.to_vec(),
                goal: vec![0.0; 3],
                rewards: vec![0.0],
                dones: vec![false],
                successes: vec![false],
                spl: vec![0.0],
                scores: vec![0.0],
            },
        };
        let mut buf = Vec::new();
        encode(&f, &mut buf);
        let out = decode_payload(FT_STEP, &buf[HEADER_LEN..]).unwrap();
        if let Frame::Step { view, .. } = out {
            for (a, b) in xs.iter().zip(&view.obs) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        } else {
            panic!("wrong frame type");
        }
    }

    #[test]
    fn hostile_headers_rejected_before_allocation() {
        // bad magic
        let h = [0xFFu8, 0xFF, VERSION, FT_HELLO, 0, 0, 0, 0];
        assert_eq!(decode_header(&h), Err(WireError::BadMagic));
        // wrong version
        let m = MAGIC.to_le_bytes();
        let h = [m[0], m[1], 99, FT_HELLO, 0, 0, 0, 0];
        assert_eq!(decode_header(&h), Err(WireError::BadVersion(99)));
        // unknown type
        let h = [m[0], m[1], VERSION, 0xEE, 0, 0, 0, 0];
        assert_eq!(decode_header(&h), Err(WireError::UnknownType(0xEE)));
        // oversized length field
        let h = [m[0], m[1], VERSION, FT_STEP, 0xFF, 0xFF, 0xFF, 0xFF];
        assert_eq!(decode_header(&h), Err(WireError::Oversized(u32::MAX)));
    }

    #[test]
    fn hostile_payloads_rejected_without_panic() {
        // truncated: LEASE needs 13 bytes
        assert_eq!(
            decode_payload(FT_LEASE, &[0u8; 4]),
            Err(WireError::Truncated)
        );
        // count field larger than the payload it announces
        let mut buf = Vec::new();
        encode(
            &Frame::Submit {
                session: 1,
                pairs: vec![(0, 1)],
            },
            &mut buf,
        );
        let mut payload = buf[HEADER_LEN..].to_vec();
        payload[8..12].copy_from_slice(&u32::MAX.to_le_bytes()); // pairs count
        assert_eq!(decode_payload(FT_SUBMIT, &payload), Err(WireError::Truncated));
        // trailing garbage after a valid body
        let mut buf = Vec::new();
        encode(&Frame::Detach { session: 9 }, &mut buf);
        let mut payload = buf[HEADER_LEN..].to_vec();
        payload.push(0xAB);
        assert_eq!(
            decode_payload(FT_DETACH, &payload),
            Err(WireError::Malformed("trailing bytes"))
        );
        // unknown task byte
        let mut buf = Vec::new();
        encode(
            &Frame::Lease {
                req: 1,
                task: Task::PointNav,
                n_envs: 1,
            },
            &mut buf,
        );
        let mut payload = buf[HEADER_LEN..].to_vec();
        payload[8] = 77;
        assert_eq!(
            decode_payload(FT_LEASE, &payload),
            Err(WireError::Malformed("unknown task"))
        );
    }

    /// Direction-aware reads reject wrong-direction and
    /// oversized-for-their-type frames from the header alone.
    #[test]
    fn direction_caps_reject_before_allocation() {
        use std::io::Cursor;
        // a "STEP" aimed at the server: legal type, wrong direction —
        // the 32 MiB length must never be allocated
        let m = MAGIC.to_le_bytes();
        let mut hdr = vec![m[0], m[1], VERSION, FT_STEP];
        hdr.extend_from_slice(&((32u32 << 20).to_le_bytes()));
        match read_frame_dir(&mut Cursor::new(hdr), true) {
            Err(ReadError::Wire(WireError::Malformed(_))) => {}
            other => panic!("want direction rejection, got {other:?}"),
        }
        // a SUBMIT whose length field exceeds the per-type cap
        let mut hdr = vec![m[0], m[1], VERSION, FT_SUBMIT];
        hdr.extend_from_slice(&((1u32 << 20).to_le_bytes()));
        match read_frame_dir(&mut Cursor::new(hdr), true) {
            Err(ReadError::Wire(WireError::Oversized(_))) => {}
            other => panic!("want per-type oversize rejection, got {other:?}"),
        }
        // every legitimate direction still round-trips
        let mut buf = Vec::new();
        encode(
            &Frame::Lease {
                req: 1,
                task: Task::PointNav,
                n_envs: 4,
            },
            &mut buf,
        );
        assert!(read_frame_dir(&mut Cursor::new(buf), true).is_ok());
        let mut buf = Vec::new();
        encode(&Frame::Welcome { shards: 2 }, &mut buf);
        assert!(read_frame_dir(&mut Cursor::new(buf), false).is_ok());
        // and the caps agree with what encode actually produces
        assert_eq!(payload_cap(FT_HELLO, true), Some(0));
        assert_eq!(payload_cap(FT_LEASE, true), Some(13));
        assert_eq!(payload_cap(FT_DETACH, true), Some(8));
        assert_eq!(payload_cap(FT_STEP, true), None);
        assert_eq!(payload_cap(FT_SUBMIT, false), None);
    }

    #[test]
    fn read_frame_distinguishes_clean_close_from_truncation() {
        use std::io::Cursor;
        // empty stream: clean EOF
        match read_frame(&mut Cursor::new(Vec::<u8>::new())) {
            Err(ReadError::Eof) => {}
            other => panic!("want Eof, got {other:?}"),
        }
        // half a header: truncated
        let mut buf = Vec::new();
        encode(&Frame::Hello, &mut buf);
        match read_frame(&mut Cursor::new(buf[..4].to_vec())) {
            Err(ReadError::Wire(WireError::Truncated)) => {}
            other => panic!("want Truncated, got {other:?}"),
        }
        // header announcing more payload than the stream carries
        let mut buf = Vec::new();
        encode(&Frame::Welcome { shards: 1 }, &mut buf);
        match read_frame(&mut Cursor::new(buf[..HEADER_LEN + 2].to_vec())) {
            Err(ReadError::Wire(WireError::Truncated)) => {}
            other => panic!("want Truncated, got {other:?}"),
        }
        // a full frame reads back
        match read_frame(&mut Cursor::new(buf)) {
            Ok(Frame::Welcome { shards: 1 }) => {}
            other => panic!("want Welcome, got {other:?}"),
        }
    }
}
