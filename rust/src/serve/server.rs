//! [`SimServer`]: N `EnvBatch` shards behind a session front door.
//!
//! Each shard is one `EnvBatch` owned by a dedicated **shard driver
//! thread**; all shards share one `WorkerPool`, so the machine's cores are
//! scheduled across shards exactly as they are across a single big batch.
//! Clients never see the batch: [`SimServer::connect`] leases env slots
//! and returns a [`Session`](super::Session), and the shard's
//! [`Coalescer`] assembles full batch steps from the sessions' partial
//! submissions. Results are published as shared snapshots
//! ([`StepResult`]) that sessions slice into per-client views, so one
//! `EnvBatch::submit` serves every tenant of the shard.
//!
//! Synchronization is a mutex + two condvars per shard: `submitted`
//! (clients → driver: actions arrived / leases changed) and `stepped`
//! (driver → clients: the published step advanced). The driver recycles
//! `StepResult` buffers through `Arc::try_unwrap`, so the steady-state
//! serving loop allocates nothing.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::env::{EnvBatch, EnvBatchConfig, StepView};
use crate::metrics::Window;
use crate::obs::{
    Counter, EventLog, Gauge, Heartbeat, Histogram, Recorder, Registry, TraceSink, Trigger,
    Watchdog, DEFAULT_TRACE_SPANS,
};
use crate::render::SceneRotation;
use crate::scene::SceneAsset;
use crate::sim::Task;
use crate::util::json::Json;
use crate::util::pool::WorkerPool;

use super::coalescer::{Coalescer, StragglerPolicy};
use super::fault::Injector;
use super::session::Session;
use super::tenant::driver::{
    lock_tenants, quarantine_tenants, tenant_driver, Join, TenantShared, TRAJ_QUEUE,
};
use super::tenant::session::{ActionMode, TenantControl, TenantSession, TrajStep};
use super::tenant::vault::PolicyVault;

/// Driver wakeup granularity while waiting out a straggler deadline
/// (`StragglerPolicy::Deadline { ticks, .. }` waits `ticks` of these).
pub const TICK: Duration = Duration::from_millis(1);

/// How many latency samples the per-shard window keeps for p50/p95.
const LATENCY_WINDOW: usize = 4096;

/// Watchdog thresholds for the shard and tenant driver threads: they
/// beat once per published tick, so seconds of silence means the pipe
/// is wedged (a hung `env.step`, a deadlocked publish) — not idle
/// (idle drivers park in `submitted.wait` behind a [`Heartbeat::idle`]
/// marker and classify Healthy).
pub(crate) const DRIVER_DEGRADED: Duration = Duration::from_secs(2);
pub(crate) const DRIVER_STALLED: Duration = Duration::from_secs(10);

/// Slow-tick anomaly gate for the flight recorder: a tick is an
/// incident when it exceeds `SLOW_TICK_FACTOR` x the trailing p95 over
/// a `SLOW_TICK_WINDOW`-sample window — once at least
/// `SLOW_TICK_MIN_SAMPLES` ticks have established a baseline and the
/// tick clears an absolute floor (tiny shards jitter in the noise).
const SLOW_TICK_WINDOW: usize = 512;
const SLOW_TICK_MIN_SAMPLES: usize = 64;
const SLOW_TICK_FACTOR: f32 = 4.0;
const SLOW_TICK_FLOOR: Duration = Duration::from_millis(5);

/// Most expensive sessions tracked per shard for latency attribution
/// (beyond the cap, the cheapest row is evicted).
pub(crate) const SESS_LAT_CAP: usize = 1024;

/// One completed batch step, published to every session of a shard.
/// Same SoA shape as [`StepView`], but owned, so tenants on other
/// threads can hold it while the `EnvBatch` reuses its step buffers.
#[derive(Default)]
pub(crate) struct StepResult {
    pub step: u64,
    pub obs: Vec<f32>,
    pub goal: Vec<f32>,
    pub rewards: Vec<f32>,
    pub dones: Vec<bool>,
    pub successes: Vec<bool>,
    pub spl: Vec<f32>,
    pub scores: Vec<f32>,
    /// Phase timings of the tick that produced this result (latency
    /// attribution: `Ticket::wait` splits its end-to-end latency into
    /// these plus a coalesce-wait residual). `publish_us` is the
    /// *previous* tick's measured publish duration — the current one
    /// cannot know its own publish cost before being published.
    pub sim_us: u64,
    pub render_us: u64,
    pub publish_us: u64,
}

impl StepResult {
    /// Copy a step's view in, reusing this result's buffers.
    fn fill(&mut self, step: u64, v: StepView<'_>) {
        self.step = step;
        self.obs.clear();
        self.obs.extend_from_slice(v.obs);
        self.goal.clear();
        self.goal.extend_from_slice(v.goal);
        self.rewards.clear();
        self.rewards.extend_from_slice(v.rewards);
        self.dones.clear();
        self.dones.extend_from_slice(v.dones);
        self.successes.clear();
        self.successes.extend_from_slice(v.successes);
        self.spl.clear();
        self.spl.extend_from_slice(v.spl);
        self.scores.clear();
        self.scores.extend_from_slice(v.scores);
    }
}

/// Mutex-guarded per-shard state (lease table + published step).
pub(crate) struct ShardState {
    pub coal: Coalescer,
    /// Latest completed step (`result.step` steps have fully executed).
    pub result: Arc<StepResult>,
    /// Steps handed to the `EnvBatch` so far; a submit buffered now is
    /// consumed by step `issued + 1`, which is what tickets wait for.
    pub issued: u64,
    pub shutdown: bool,
    /// `shutdown` because the driver *panicked* (not a clean stop): the
    /// lease table was rebuilt, waiters get a retry-after-hinted
    /// `SHARD_DOWN` error, and [`SimServer::restart_shard`] may bring
    /// the shard back (DESIGN.md §0.12).
    pub quarantined: bool,
    pub error: Option<String>,
    /// Shard-wide submit→result latency samples (seconds).
    pub latency: Window,
    /// Per-session submit→result accumulators (the slowest-sessions
    /// table). Capped at [`SESS_LAT_CAP`] rows; the cheapest row is
    /// evicted so long-running offenders survive session churn.
    pub sess_lat: HashMap<u64, SessLat>,
}

/// Per-session latency accumulator (one row of the slowest-sessions
/// table; see [`SimServer::slowest_sessions`]).
#[derive(Clone, Copy, Default)]
pub(crate) struct SessLat {
    pub steps: u64,
    pub sum_us: u64,
    pub max_us: u64,
}

/// One row of the slowest-sessions table: a session's submit→result
/// latency profile, worst first.
#[derive(Clone, Debug)]
pub struct SessionLatency {
    pub session: u64,
    pub shard: usize,
    pub steps: u64,
    pub mean_us: u64,
    pub max_us: u64,
}

/// The `serve.session.phase_us{phase=...}` histograms: one per pipeline
/// phase of a session step. `sim`/`render`/`publish` come from the
/// driver's measured durations, `infer` from the tenant driver, and
/// `coalesce` is the residual of the end-to-end ticket latency — so for
/// in-process sessions the four non-infer phases sum to the e2e
/// histogram by construction.
pub(crate) struct PhaseObs {
    pub coalesce: Histogram,
    pub sim: Histogram,
    pub render: Histogram,
    pub infer: Histogram,
    pub publish: Histogram,
}

impl PhaseObs {
    fn new(registry: &Registry) -> PhaseObs {
        let h = |p: &str| registry.histogram("serve.session.phase_us", &[("phase", p)]);
        PhaseObs {
            coalesce: h("coalesce"),
            sim: h("sim"),
            render: h("render"),
            infer: h("infer"),
            publish: h("publish"),
        }
    }
}

/// Registry handles the shard driver feeds every tick (DESIGN.md §0.10
/// metric table). All counters, all labeled `{shard=<idx>}`.
pub(crate) struct ShardObs {
    /// `serve.shard.steps` — batch steps published.
    pub steps: Counter,
    /// `env.sim_us` / `env.render_us` — wall time per pipeline half.
    pub sim_us: Counter,
    pub render_us: Counter,
    /// `render.{transform,cull,raster,resolve}_us` — per-stage CPU time
    /// summed across render workers (`RenderCounters`).
    pub transform_us: Counter,
    pub cull_us: Counter,
    pub raster_us: Counter,
    pub resolve_us: Counter,
    /// `render.tris` / `render.chunks_{culled,total}`.
    pub tris: Counter,
    pub chunks_culled: Counter,
    pub chunks_total: Counter,
    /// `serve.shard.latency_us` — submit→result latency histogram
    /// (observed by `Ticket::wait` alongside the percentile windows).
    pub latency_us: Histogram,
    /// `serve.quarantine` — 1 while the shard is quarantined after a
    /// driver panic, 0 otherwise (cleared by `restart_shard`).
    pub quarantined: Gauge,
}

/// One shard as seen by sessions and the driver thread.
pub(crate) struct ShardShared {
    /// Shard index (stats row, metric label, trace pid).
    pub idx: usize,
    pub task: Task,
    pub slots: usize,
    pub obs_floats: usize,
    /// Resident scene-asset footprint of the shard's `EnvBatch` (the
    /// admission-control input; fixed at build time).
    pub resident_bytes: usize,
    /// Completed rotation swaps (mirrors `EnvBatch::rotations` across
    /// the driver-thread ownership boundary).
    pub rotations: Arc<AtomicU64>,
    pub state: Mutex<ShardState>,
    /// Clients → driver: actions buffered / leases changed / shutdown.
    pub submitted: Condvar,
    /// Driver → clients: `state.result` advanced (or shard failed).
    pub stepped: Condvar,
    pub obs: ShardObs,
    /// Server-wide megaframe span recorder (off until enabled).
    pub trace: Arc<TraceSink>,
    /// Server-wide lifecycle event log (disarmed until `--event-log`).
    pub events: Arc<EventLog>,
    /// The driver thread's liveness beacon (watchdog role
    /// `shard-driver`). Lives here so a dead driver keeps reporting
    /// Stalled instead of silently vanishing from `/healthz`.
    pub heartbeat: Heartbeat,
    /// Server-wide per-phase latency histograms (shared across shards;
    /// labeled by phase, not shard, to bound cardinality).
    pub phase: Arc<PhaseObs>,
    /// The flight recorder, once armed (`SimServer::arm_recorder`).
    /// Disarmed servers pay one `OnceLock` load per slow-tick check.
    pub recorder: Arc<OnceLock<Arc<Recorder>>>,
    /// The fault-injection plane, once armed (`SimServer::arm_faults`).
    /// The driver polls it for one-shot `panic:shard=` clauses; unarmed
    /// servers pay one `OnceLock` load per tick.
    pub fault: Arc<OnceLock<Arc<Injector>>>,
}

/// Lock a shard's state, recovering from mutex poisoning: a panicking
/// driver (or fault-injected panic) must never cascade `PoisonError`
/// panics into every session thread — quarantine rebuilds the state
/// coherently instead (DESIGN.md §0.12). Every shard-state lock site in
/// `serve` goes through this.
pub(crate) fn lock_state(m: &Mutex<ShardState>) -> MutexGuard<'_, ShardState> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Lock the server's per-shard tenant-registry table with the same
/// poison-recovery contract as [`lock_state`]/`lock_tenants`: a tenant
/// driver that panicked mid-update has already quarantined its shard, so
/// readers (stats, shutdown, new leases) must keep working rather than
/// cascade the `PoisonError`. Every `tenancy` lock site goes through
/// this (enforced by `bps lint` rule L003).
pub(crate) fn lock_tenancy(
    m: &Mutex<Vec<Option<Arc<TenantShared>>>>,
) -> MutexGuard<'_, Vec<Option<Arc<TenantShared>>>> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl ShardShared {
    pub fn fail(&self, msg: String) {
        let mut st = lock_state(&self.state);
        st.shutdown = true;
        st.error = Some(msg);
        self.submitted.notify_all();
        self.stepped.notify_all();
    }

    /// Panic isolation: the driver thread died mid-step. Mark the shard
    /// quarantined, rebuild the lease table (every lease is gone — the
    /// env state behind it is unrecoverable), wake all waiters with a
    /// retry-after-hinted error, flip the watchdog role terminal, and
    /// cut a `driver.panic` flight-recorder bundle.
    pub(crate) fn quarantine(&self, what: &str) {
        let msg = format!(
            "shard {} quarantined: driver panicked: {what}",
            self.idx
        );
        {
            let mut st = lock_state(&self.state);
            st.shutdown = true;
            st.quarantined = true;
            st.error = Some(msg.clone());
            // The lease table may be mid-mutation from the panicking
            // step: clear it wholesale so a later restart starts from a
            // coherent, empty table (sessions are dead either way).
            st.coal.clear_leases();
            self.submitted.notify_all();
            self.stepped.notify_all();
        }
        self.heartbeat.dead();
        self.obs.quarantined.set(1.0);
        self.events.emit(
            "shard.quarantine",
            &[
                ("shard", Json::Num(self.idx as f64)),
                ("reason", Json::Str(what.to_string())),
            ],
        );
        if let Some(rec) = self.recorder.get() {
            let _ = rec.trigger(Trigger::DriverPanic(msg));
        }
    }
}

/// Render a caught panic payload for error messages.
pub(crate) fn panic_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The shard driver loop: coalesce → step → publish — and, for shards
/// with a scenario/rotation assignment, stream fresh scenes in by driving
/// `rotate_scenes` every `rotate_every` steps — until shutdown.
fn shard_driver(shared: Arc<ShardShared>, mut env: EnvBatch, rotate_every: Option<u64>) {
    let mut actions: Vec<u8> = Vec::with_capacity(shared.slots);
    let mut spare: Option<StepResult> = None;
    // Publish cost of the previous tick (stamped into the next result's
    // `publish_us` — see `StepResult`) and the trailing tick-duration
    // window backing the slow-tick anomaly trigger.
    let mut last_publish_us: u64 = 0;
    let mut ticks = Window::new(SLOW_TICK_WINDOW);
    loop {
        // Fault plane: an armed `panic:shard=IDX` clause fires here,
        // outside the state lock, so injected panics exercise the same
        // quarantine path as organic ones without poisoning the mutex
        // (which quarantine tolerates anyway — see `lock_state`).
        if let Some(inj) = shared.fault.get() {
            if inj.take_panic(shared.idx) {
                panic!("fault injection: panic:shard={}", shared.idx);
            }
        }
        let wait_from = shared.trace.now_us();
        // Phase 1: wait until a full batch can be assembled.
        let step_no = {
            let mut st = lock_state(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.coal.ready() {
                    break;
                }
                match st.coal.policy() {
                    StragglerPolicy::Deadline { ticks, .. } if st.coal.has_pending() => {
                        if st.coal.waited() >= ticks {
                            break; // deadline passed: fill stragglers
                        }
                        let (guard, timeout) = shared
                            .submitted
                            .wait_timeout(st, TICK)
                            .unwrap_or_else(|e| e.into_inner());
                        st = guard;
                        if timeout.timed_out() {
                            st.coal.tick();
                        }
                    }
                    _ => {
                        // Deliberate unbounded park: tell the watchdog
                        // this silence is idleness, not a stall.
                        shared.heartbeat.idle();
                        st = shared
                            .submitted
                            .wait(st)
                            .unwrap_or_else(|e| e.into_inner());
                    }
                }
            }
            st.coal.assemble(&mut actions);
            st.issued += 1;
            st.issued
        };
        // Beat *after* assembly so a tick wedged in sim/render/publish
        // below goes silent and trips the watchdog.
        shared.heartbeat.beat();
        // Phase 2: step the batch outside the lock (sim + render).
        let step_from = shared.trace.now_us();
        let mut r = match env.step(&actions) {
            Ok(view) => {
                let mut r = spare.take().unwrap_or_default();
                r.fill(step_no, view);
                r
            }
            Err(e) => {
                shared.fail(format!("shard step failed: {e:#}"));
                return;
            }
        };
        // Producer drains happen every tick (the underlying cells are
        // reset-on-read), feeding the registry counters; none of this
        // touches the step data, so serving stays bitwise-identical
        // with obs on or off.
        let (sim_d, render_d) = env.drain_timings();
        r.sim_us = sim_d.as_micros() as u64;
        r.render_us = render_d.as_micros() as u64;
        r.publish_us = last_publish_us;
        let result = Arc::new(r);
        let rs = env.take_render_stats();
        let o = &shared.obs;
        o.sim_us.add(sim_d.as_micros() as u64);
        o.render_us.add(render_d.as_micros() as u64);
        o.transform_us.add(rs.transform_ns / 1_000);
        o.cull_us.add(rs.cull_ns / 1_000);
        o.raster_us.add(rs.raster_ns / 1_000);
        o.resolve_us.add(rs.resolve_ns / 1_000);
        o.tris.add(rs.tris_rasterized as u64);
        o.chunks_culled.add(rs.chunks_culled as u64);
        o.chunks_total.add(rs.chunks_total as u64);
        if shared.trace.enabled() {
            let pid = shared.idx as u32;
            let t = &shared.trace;
            let wait = Duration::from_micros(step_from.saturating_sub(wait_from));
            t.span(pid, "driver", "coalesce", wait_from, wait, step_no);
            t.span(pid, "driver", "sim", step_from, sim_d, step_no);
            let render_from = step_from + sim_d.as_micros() as u64;
            t.span(pid, "driver", "render", render_from, render_d, step_no);
            // Stage durations are CPU time summed across render workers
            // (can exceed the render wall span); they are laid out
            // sequentially from the render start on their own lane.
            let mut at = render_from;
            for (name, ns) in [
                ("render.transform", rs.transform_ns),
                ("render.cull", rs.cull_ns),
                ("render.raster", rs.raster_ns),
                ("render.resolve", rs.resolve_ns),
            ] {
                t.span(pid, "render-stages", name, at, Duration::from_nanos(ns), step_no);
                at += (ns / 1_000).max(1);
            }
        }
        // Phase 3: publish, then reclaim the old snapshot's buffers if no
        // session still holds it. Publish is timed unconditionally (an
        // `Instant` pair, not a trace read) because the next tick stamps
        // it into `StepResult::publish_us` for latency attribution.
        let publish_from = shared.trace.now_us();
        let publish_started = Instant::now();
        let prev = {
            let mut st = lock_state(&shared.state);
            // Counter inc and snapshot swap share the critical section,
            // so a locked stats() read always sees them agree.
            shared.obs.steps.inc();
            let prev = std::mem::replace(&mut st.result, result);
            shared.stepped.notify_all();
            prev
        };
        let publish_d = publish_started.elapsed();
        last_publish_us = publish_d.as_micros() as u64;
        if shared.trace.enabled() {
            shared
                .trace
                .span(shared.idx as u32, "driver", "publish", publish_from, publish_d, step_no);
        }
        if let Ok(r) = Arc::try_unwrap(prev) {
            spare = Some(r);
        }
        // Slow-tick anomaly: only evaluated with a flight recorder armed
        // (the p95 scan costs a sort; disarmed servers pay one `OnceLock`
        // load and one window push). Checked against the *trailing*
        // window, before this tick joins it.
        let tick_d = sim_d + render_d + publish_d;
        if let Some(rec) = shared.recorder.get() {
            if ticks.len() >= SLOW_TICK_MIN_SAMPLES && tick_d > SLOW_TICK_FLOOR {
                let [p95] = ticks.percentiles([0.95]);
                if tick_d.as_secs_f32() > SLOW_TICK_FACTOR * p95 {
                    let _ = rec.trigger(Trigger::SlowTick {
                        tick_us: tick_d.as_micros() as u64,
                        p95_us: (p95 * 1e6) as u64,
                    });
                }
            }
        }
        ticks.push(tick_d.as_secs_f32());
        // Phase 4: scene streaming for served shards (the training loop's
        // once-per-iteration rotate, at the shard's own cadence). A no-op
        // for shards built over a fixed scene assignment.
        if let Some(every) = rotate_every {
            if step_no % every == 0 {
                if let Err(e) = env.rotate_scenes() {
                    shared.fail(format!("shard rotate failed: {e:#}"));
                    return;
                }
            }
        }
    }
}

/// Spawn a shard-driver thread with panic isolation: a panic anywhere
/// in the driver loop quarantines the shard (typed errors to its
/// sessions, terminal watchdog state, `driver.panic` bundle) instead of
/// unwinding into the process default and taking the server down.
fn spawn_driver(
    shared: &Arc<ShardShared>,
    env: EnvBatch,
    rotate_every: Option<u64>,
) -> Result<JoinHandle<()>> {
    let for_driver = Arc::clone(shared);
    std::thread::Builder::new()
        .name("sim-serve-shard".into())
        .spawn(move || {
            let inner = Arc::clone(&for_driver);
            let r = catch_unwind(AssertUnwindSafe(move || {
                shard_driver(inner, env, rotate_every)
            }));
            if let Err(e) = r {
                for_driver.quarantine(&panic_msg(e.as_ref()));
            }
        })
        .map_err(|e| anyhow!("spawn shard driver thread: {e}"))
}

/// Retained build inputs for [`SimServer::restart_shard`]. Only
/// fixed-scene shards are restartable: a [`SceneRotation`] is consumed
/// by its `EnvBatch` at build time and cannot be re-split.
struct ShardRebuild {
    cfg: EnvBatchConfig,
    scenes: Vec<Arc<SceneAsset>>,
    rotate_every: Option<u64>,
}

/// JSON rendering of the slowest-sessions table over `shards` (the
/// flight recorder's `sessions.json` artifact; same rows as
/// [`SimServer::slowest_sessions`]).
pub(crate) fn sessions_json(shards: &[Arc<ShardShared>], n: usize) -> Json {
    let mut rows: Vec<(u64, usize, SessLat)> = Vec::new();
    for sh in shards {
        let st = lock_state(&sh.state);
        for (&session, lat) in &st.sess_lat {
            rows.push((session, sh.idx, *lat));
        }
    }
    rows.sort_by(|a, b| b.2.max_us.cmp(&a.2.max_us).then(a.0.cmp(&b.0)));
    rows.truncate(n);
    let arr = rows
        .into_iter()
        .map(|(session, shard, lat)| {
            let mut o = std::collections::BTreeMap::new();
            o.insert("session".to_string(), Json::Num(session as f64));
            o.insert("shard".to_string(), Json::Num(shard as f64));
            o.insert("steps".to_string(), Json::Num(lat.steps as f64));
            let mean = if lat.steps == 0 { 0 } else { lat.sum_us / lat.steps };
            o.insert("mean_us".to_string(), Json::Num(mean as f64));
            o.insert("max_us".to_string(), Json::Num(lat.max_us as f64));
            Json::Obj(o)
        })
        .collect();
    let mut top = std::collections::BTreeMap::new();
    top.insert("slowest_sessions".to_string(), Json::Arr(arr));
    Json::Obj(top)
}

/// Where a shard's environments get their scenes (mirrors the two
/// [`EnvBatchConfig`] build paths).
pub enum SceneSource {
    /// Explicit env → scene assignment; the batch size is `scenes.len()`.
    Scenes(Vec<Arc<SceneAsset>>),
    /// `n` envs over a K-slot rotation (dataset- or scenario-fed). Pair
    /// with [`ShardSpec::rotate_every`] so the shard driver streams fresh
    /// scenes in; without it the rotation only provides initial residency.
    Rotation { rotation: SceneRotation, n: usize },
}

/// Everything needed to stand up one shard of a [`SimServer`].
pub struct ShardSpec {
    pub cfg: EnvBatchConfig,
    pub source: SceneSource,
    pub straggler: StragglerPolicy,
    /// `Some(k)`: the shard driver calls `rotate_scenes` every k batch
    /// steps, so served shards stream scenes exactly like training
    /// shards. Gated on a rotation assignment — fixed-scene shards have
    /// nothing to rotate and leave this `None`.
    pub rotate_every: Option<u64>,
}

impl ShardSpec {
    /// A shard over an explicit scene assignment, defaulting to the
    /// deterministic `Wait` coalescing policy.
    pub fn with_scenes(cfg: EnvBatchConfig, scenes: Vec<Arc<SceneAsset>>) -> ShardSpec {
        ShardSpec {
            cfg,
            source: SceneSource::Scenes(scenes),
            straggler: StragglerPolicy::Wait,
            rotate_every: None,
        }
    }

    /// A shard of `n` envs over a K-slot scene rotation.
    pub fn with_rotation(cfg: EnvBatchConfig, rotation: SceneRotation, n: usize) -> ShardSpec {
        ShardSpec {
            cfg,
            source: SceneSource::Rotation { rotation, n },
            straggler: StragglerPolicy::Wait,
            rotate_every: None,
        }
    }

    /// Override the straggler policy for this shard's coalescer.
    pub fn straggler(mut self, policy: StragglerPolicy) -> ShardSpec {
        self.straggler = policy;
        self
    }

    /// Stream scenes from the shard driver: one `rotate_scenes` call
    /// every `every` batch steps (requires a rotation scene source).
    pub fn rotate_every(mut self, every: u64) -> ShardSpec {
        self.rotate_every = Some(every.max(1));
        self
    }
}

/// Point-in-time counters for a shard's policy tenancy (present once a
/// shard has hosted a policy lease; see [`SimServer::stats`]).
#[derive(Clone, Debug)]
pub struct TenantStats {
    /// Policy tenants currently registered on the shard.
    pub tenants: usize,
    /// Server-driven env steps, cumulative (the agent-steps/sec
    /// numerator).
    pub agent_steps: u64,
    /// Coalesced `Exec::run` invocations, cumulative — with every tenant
    /// on one variant this equals the tick count regardless of tenant
    /// count, which is the whole point.
    pub infer_runs: u64,
    /// Rows per coalesced forward (the shard width: tenants are rows of
    /// one batched inference).
    pub infer_batch_size: usize,
    /// Registered-but-idle member-ticks the straggler policy filled.
    pub idle_fills: u64,
    // Per-stage tick latency percentiles (seconds).
    pub infer_p50: f32,
    pub infer_p95: f32,
    pub gather_p50: f32,
    pub gather_p95: f32,
    pub step_p50: f32,
    pub step_p95: f32,
}

/// Point-in-time counters for one shard (see [`SimServer::stats`]).
#[derive(Clone, Debug)]
pub struct ShardStats {
    pub task: Task,
    /// Total env slots in the shard.
    pub slots: usize,
    /// Slots currently leased to sessions (occupancy numerator).
    pub leased: usize,
    /// Actions buffered in the coalescer awaiting the next step.
    pub queued_actions: usize,
    /// Batch steps completed since start.
    pub steps: u64,
    /// Leased slots the straggler policy had to fill, cumulative.
    pub straggler_fills: u64,
    /// Submissions rejected for a bad slot index (out of range, unleased,
    /// or foreign), cumulative. Nonzero only under hostile or buggy
    /// clients — slot indices arrive off the wire (`serve::wire`).
    pub bad_submits: u64,
    /// Scene-rotation swaps the shard driver has performed.
    pub rotations: u64,
    /// Resident scene-asset footprint (admission-control input).
    pub resident_bytes: usize,
    /// Submit→result latency percentiles over recent steps (seconds).
    pub latency_p50: f32,
    pub latency_p95: f32,
    /// Policy-tenancy counters, once the shard has hosted a policy lease.
    pub tenant: Option<TenantStats>,
}

impl ShardStats {
    /// Leased fraction of the shard's slots.
    pub fn occupancy(&self) -> f32 {
        if self.slots == 0 {
            return 0.0;
        }
        self.leased as f32 / self.slots as f32
    }
}

/// Why [`SimServer::try_connect`] declined a lease. The wire server
/// maps `Overload` to a retry-after [`ERR_RETRY_AFTER`]
/// (`super::wire::frame::ERR_RETRY_AFTER`) error frame — the client
/// should back off and retry — and `NoCapacity` to a plain `ERR_LEASE`.
#[derive(Debug)]
pub enum LeaseDecline {
    /// Admission control: granting now would blow the memory budget.
    /// Retryable once sessions on other shards detach.
    Overload(String),
    /// No shard can host the lease (wrong task, not enough free slots,
    /// or the matching shards are down).
    NoCapacity(String),
}

impl LeaseDecline {
    pub fn message(&self) -> &str {
        match self {
            LeaseDecline::Overload(m) | LeaseDecline::NoCapacity(m) => m,
        }
    }
}

/// The multi-tenant simulation server (see module docs).
pub struct SimServer {
    shards: Vec<Arc<ShardShared>>,
    /// Driver threads, including replacements spawned by
    /// [`restart_shard`](SimServer::restart_shard) (a mutex so restart
    /// takes `&self` like every other server entry point).
    drivers: Mutex<Vec<JoinHandle<()>>>,
    /// Per-shard retained build inputs (`None`: rotation-fed, not
    /// restartable in place).
    rebuilds: Vec<Option<ShardRebuild>>,
    /// The shared worker pool, retained for shard rebuilds.
    pool: Arc<WorkerPool>,
    next_session: AtomicU64,
    /// Admission control: reject leases whose projected active resident
    /// bytes across shards would exceed this budget (`None` = unlimited).
    mem_budget: Option<usize>,
    /// Serializes `connect` so the activation snapshot admission reads
    /// cannot race another admission decision.
    admission: Mutex<()>,
    /// Policy checkpoints for tenant leases (`None`: the server serves
    /// envs only and `connect_with_policy` is declined — the artifact
    /// gate).
    vault: Option<Arc<PolicyVault>>,
    /// Per-shard tenant registries, created with the shard's first
    /// policy lease (each spawns one tenant driver thread).
    tenancy: Mutex<Vec<Option<Arc<TenantShared>>>>,
    tenant_drivers: Mutex<Vec<JoinHandle<()>>>,
    /// The obs substrate (DESIGN.md §0.10): every producer on this server
    /// registers here; every scrape (HTTP, `STATS` frame, `stats()`)
    /// reads from here.
    registry: Arc<Registry>,
    trace: Arc<TraceSink>,
    events: Arc<EventLog>,
    /// Liveness monitor over every long-lived thread of this server
    /// (shard/tenant drivers, wire pumps, procgen). Backs `/healthz`.
    watchdog: Arc<Watchdog>,
    /// The flight recorder slot, empty until [`arm_recorder`]
    /// (`SimServer::arm_recorder`) — shared with every shard so the
    /// drivers' slow-tick checks see the same armed state.
    recorder: Arc<OnceLock<Arc<Recorder>>>,
    /// The fault-injection slot, empty until [`arm_faults`]
    /// (`SimServer::arm_faults`) — shared with every shard driver.
    fault: Arc<OnceLock<Arc<Injector>>>,
    /// `serve.shed.admission` — leases declined by admission control
    /// (answered with retry-after, never silently).
    shed_admission: Counter,
}

impl SimServer {
    /// Build every shard's `EnvBatch` and start one driver thread per
    /// shard. Shards may be heterogeneous (different tasks / render
    /// configs); they share `pool`. No admission budget — see
    /// [`with_budget`](SimServer::with_budget).
    pub fn start(specs: Vec<ShardSpec>, pool: Arc<WorkerPool>) -> Result<SimServer> {
        SimServer::with_budget(specs, pool, None)
    }

    /// [`start`](SimServer::start) with admission control: a lease is
    /// rejected when the resident scene-asset bytes of *active* shards
    /// (shards with at least one leased slot, plus the candidate) would
    /// exceed `mem_budget` bytes. An idle shard's assets are treated as
    /// evictable, so tenants can still be steered onto already-active
    /// shards under memory pressure.
    pub fn with_budget(
        specs: Vec<ShardSpec>,
        pool: Arc<WorkerPool>,
        mem_budget: Option<usize>,
    ) -> Result<SimServer> {
        SimServer::with_vault(specs, pool, mem_budget, None)
    }

    /// [`with_budget`](SimServer::with_budget) plus a [`PolicyVault`]:
    /// with one, sessions may lease a policy alongside their env slots
    /// ([`connect_with_policy`](SimServer::connect_with_policy)) and the
    /// server closes the act→observe loop itself. Without one, policy
    /// leases are declined with a clear error — exactly the
    /// `artifacts/manifest.json` gate the coordinator's eval uses.
    pub fn with_vault(
        specs: Vec<ShardSpec>,
        pool: Arc<WorkerPool>,
        mem_budget: Option<usize>,
        vault: Option<PolicyVault>,
    ) -> Result<SimServer> {
        if specs.is_empty() {
            bail!("SimServer needs at least one shard");
        }
        let registry = Registry::new();
        let trace = Arc::new(TraceSink::new(DEFAULT_TRACE_SPANS));
        let events = Arc::new(EventLog::disabled());
        let watchdog = Watchdog::start(Arc::clone(&registry), Arc::clone(&events));
        let recorder: Arc<OnceLock<Arc<Recorder>>> = Arc::new(OnceLock::new());
        let fault: Arc<OnceLock<Arc<Injector>>> = Arc::new(OnceLock::new());
        let phase = Arc::new(PhaseObs::new(&registry));
        let mut shards = Vec::with_capacity(specs.len());
        let mut drivers = Vec::with_capacity(specs.len());
        let mut rebuilds = Vec::with_capacity(specs.len());
        for spec in specs {
            let ShardSpec {
                cfg,
                source,
                straggler,
                rotate_every,
            } = spec;
            // The shard driver always submits and immediately waits, so
            // the EnvBatch's own pipelined driver thread would add a
            // channel round-trip per step with zero overlap benefit:
            // force the (bitwise-identical) synchronous path.
            let cfg = cfg.overlap(false);
            // Fixed-scene shards retain their build inputs (the scene
            // Arcs are shared, not copied) so `restart_shard` can
            // rebuild the EnvBatch in place after a quarantine.
            let (env, rebuild) = match source {
                SceneSource::Scenes(scenes) => {
                    let env = cfg.build_with_scenes(scenes.clone(), Arc::clone(&pool))?;
                    (env, Some(ShardRebuild { cfg, scenes, rotate_every }))
                }
                SceneSource::Rotation { rotation, n } => {
                    (cfg.build_with_rotation(rotation, n, Arc::clone(&pool))?, None)
                }
            };
            let slots = env.num_envs();
            // Publish the initial observation as step 0 so sessions can
            // read a view before their first submit.
            let mut initial = StepResult::default();
            initial.fill(0, env.view());
            // Register this shard's series. The coalescer's counters and
            // gauges are attached (not copied), so `stats()` and a scrape
            // read identical cells.
            let idx = shards.len();
            let sid = idx.to_string();
            let l: &[(&str, &str)] = &[("shard", &sid)];
            let coal = Coalescer::new(slots, straggler);
            registry.attach_counter("serve.shard.straggler_fills", l, &coal.straggler_fills);
            registry.attach_counter("serve.shard.bad_submits", l, &coal.bad_submits);
            registry.attach_gauge("serve.shard.leased", l, &coal.obs_leased);
            registry.attach_gauge("serve.shard.queued_actions", l, &coal.obs_queued);
            registry.attach_gauge("serve.shard.occupancy", l, &coal.obs_occupancy);
            registry.gauge("serve.shard.slots", l).set(slots as f64);
            registry.attach_counter(
                "env.rotations",
                l,
                &Counter::from_cell(env.rotations_counter()),
            );
            registry.attach_counter(
                "scenario.feed_stalls",
                l,
                &Counter::from_cell(env.feed_stalls_counter()),
            );
            let obs = ShardObs {
                steps: registry.counter("serve.shard.steps", l),
                sim_us: registry.counter("env.sim_us", l),
                render_us: registry.counter("env.render_us", l),
                transform_us: registry.counter("render.transform_us", l),
                cull_us: registry.counter("render.cull_us", l),
                raster_us: registry.counter("render.raster_us", l),
                resolve_us: registry.counter("render.resolve_us", l),
                tris: registry.counter("render.tris", l),
                chunks_culled: registry.counter("render.chunks_culled", l),
                chunks_total: registry.counter("render.chunks_total", l),
                latency_us: registry.histogram("serve.shard.latency_us", l),
                quarantined: registry.gauge("serve.quarantine", l),
            };
            // Liveness: the driver thread beats per tick; a scenario-fed
            // shard also carries its procgen generator's heartbeat
            // (created with the stream, adopted here).
            let heartbeat = watchdog.register("shard-driver", DRIVER_DEGRADED, DRIVER_STALLED);
            if let Some(hb) = env.procgen_heartbeat() {
                watchdog.adopt(&hb);
            }
            let shared = Arc::new(ShardShared {
                idx,
                task: env.task(),
                slots,
                obs_floats: env.obs_floats(),
                resident_bytes: env.resident_bytes(),
                rotations: env.rotations_counter(),
                state: Mutex::new(ShardState {
                    coal,
                    result: Arc::new(initial),
                    issued: 0,
                    shutdown: false,
                    quarantined: false,
                    error: None,
                    latency: Window::new(LATENCY_WINDOW),
                    sess_lat: HashMap::new(),
                }),
                submitted: Condvar::new(),
                stepped: Condvar::new(),
                obs,
                trace: Arc::clone(&trace),
                events: Arc::clone(&events),
                heartbeat,
                phase: Arc::clone(&phase),
                recorder: Arc::clone(&recorder),
                fault: Arc::clone(&fault),
            });
            let driver = spawn_driver(&shared, env, rotate_every)?;
            shards.push(shared);
            drivers.push(driver);
            rebuilds.push(rebuild);
        }
        let n_shards = shards.len();
        let shed_admission = registry.counter("serve.shed.admission", &[]);
        Ok(SimServer {
            shards,
            drivers: Mutex::new(drivers),
            rebuilds,
            pool,
            next_session: AtomicU64::new(1),
            mem_budget,
            admission: Mutex::new(()),
            vault: vault.map(Arc::new),
            tenancy: Mutex::new((0..n_shards).map(|_| None).collect()),
            tenant_drivers: Mutex::new(Vec::new()),
            registry,
            trace,
            events,
            watchdog,
            recorder,
            fault,
            shed_admission,
        })
    }

    /// Whether this server holds a policy vault (policy leases possible).
    pub fn has_vault(&self) -> bool {
        self.vault.is_some()
    }

    /// The server's metrics registry (scrape surface substrate).
    pub fn registry(&self) -> Arc<Registry> {
        Arc::clone(&self.registry)
    }

    /// The server's megaframe span recorder. Disabled until
    /// [`TraceSink::enable`]; export via [`TraceSink::to_chrome_json`].
    pub fn trace(&self) -> Arc<TraceSink> {
        Arc::clone(&self.trace)
    }

    /// The server's lifecycle event log. Disarmed until
    /// [`EventLog::arm`].
    pub fn events(&self) -> Arc<EventLog> {
        Arc::clone(&self.events)
    }

    /// The server's health watchdog (readiness source for `/healthz`,
    /// fault injection for tests and drills).
    pub fn watchdog(&self) -> Arc<Watchdog> {
        Arc::clone(&self.watchdog)
    }

    /// The flight recorder, if armed.
    pub fn recorder(&self) -> Option<Arc<Recorder>> {
        self.recorder.get().cloned()
    }

    /// Arm the flight recorder: incident bundles land under `dir`.
    /// From here on stalls, slow ticks, panics (if hooked), and manual
    /// dumps each produce a bundle — rate-limited and retention-capped
    /// (see [`Recorder`]). One-shot: arming twice is an error.
    pub fn arm_recorder(&self, dir: &Path) -> Result<Arc<Recorder>> {
        let rec = Arc::new(Recorder::new(
            dir,
            Arc::clone(&self.registry),
            Arc::clone(&self.trace),
            Arc::clone(&self.events),
        )?);
        // Bundle extras capture weak refs: the recorder must not keep
        // the server (or the watchdog that holds the recorder) alive.
        let wd = Arc::downgrade(&self.watchdog);
        rec.add_artifact("watchdog.json", move || {
            wd.upgrade()
                .map(|w| w.table_json().to_string())
                .unwrap_or_else(|| "{}".to_string())
        });
        let shards: Vec<Weak<ShardShared>> = self.shards.iter().map(Arc::downgrade).collect();
        rec.add_artifact("sessions.json", move || {
            let shards: Vec<Arc<ShardShared>> =
                shards.iter().filter_map(Weak::upgrade).collect();
            sessions_json(&shards, 16).to_string()
        });
        if self.recorder.set(Arc::clone(&rec)).is_err() {
            bail!("flight recorder already armed");
        }
        self.watchdog.set_recorder(Arc::clone(&rec));
        Ok(rec)
    }

    /// Arm the fault-injection plane (one-shot): shard drivers start
    /// polling for `panic:shard=` clauses, and any `stall:role=` clauses
    /// pin their watchdog roles immediately. The wire server shares the
    /// same injector through its `WireConfig` for the connection-level
    /// faults (drops, delays, corruption).
    pub fn arm_faults(&self, inj: Arc<Injector>) -> Result<()> {
        for role in inj.stall_roles() {
            self.watchdog.inject_stall(role);
        }
        if self.fault.set(inj).is_err() {
            bail!("fault plane already armed");
        }
        Ok(())
    }

    /// The armed fault injector, if any.
    pub fn injector(&self) -> Option<Arc<Injector>> {
        self.fault.get().cloned()
    }

    /// Whether shard `idx` is quarantined after a driver panic.
    pub fn shard_quarantined(&self, idx: usize) -> bool {
        self.shards
            .get(idx)
            .is_some_and(|sh| lock_state(&sh.state).quarantined)
    }

    /// Rebuild a quarantined shard in place: a fresh `EnvBatch` from the
    /// retained build inputs, an already-cleared lease table, a revived
    /// watchdog role, and a new driver thread. Geometry (slots, obs
    /// shape, task) is unchanged, so every stats row and wire invariant
    /// stays valid. Declines when the shard is healthy (never clobber a
    /// live driver) or was rotation-fed (the rotation was consumed at
    /// build time — restart the server instead).
    pub fn restart_shard(&self, idx: usize) -> Result<()> {
        let shard = self
            .shards
            .get(idx)
            .ok_or_else(|| anyhow!("restart_shard: no shard {idx}"))?;
        // Serialize with admission (and concurrent restarts): the
        // quarantine check and the driver spawn must be atomic.
        let _admission = self.admission.lock().unwrap();
        if !lock_state(&shard.state).quarantined {
            bail!("restart_shard: shard {idx} is not quarantined");
        }
        let rb = self.rebuilds[idx].as_ref().ok_or_else(|| {
            anyhow!(
                "restart_shard: shard {idx} was built over a scene rotation, \
                 which is consumed at build time — restart the server"
            )
        })?;
        let env = rb
            .cfg
            .overlap(false)
            .build_with_scenes(rb.scenes.clone(), Arc::clone(&self.pool))?;
        let mut initial = StepResult::default();
        initial.fill(0, env.view());
        {
            let mut st = lock_state(&shard.state);
            st.coal.clear_leases();
            st.result = Arc::new(initial);
            st.issued = 0;
            st.shutdown = false;
            st.quarantined = false;
            st.error = None;
            st.sess_lat.clear();
        }
        shard.heartbeat.revive();
        shard.obs.quarantined.set(0.0);
        shard
            .events
            .emit("shard.restart", &[("shard", Json::Num(idx as f64))]);
        let driver = spawn_driver(shard, env, rb.rotate_every)?;
        self.drivers.lock().unwrap().push(driver);
        Ok(())
    }

    /// The `n` slowest sessions by peak submit→result latency, across
    /// all shards (the latency-attribution table surfaced in shutdown
    /// stats and incident bundles).
    pub fn slowest_sessions(&self, n: usize) -> Vec<SessionLatency> {
        let mut rows: Vec<SessionLatency> = Vec::new();
        for sh in &self.shards {
            let st = lock_state(&sh.state);
            for (&session, lat) in &st.sess_lat {
                rows.push(SessionLatency {
                    session,
                    shard: sh.idx,
                    steps: lat.steps,
                    mean_us: if lat.steps == 0 { 0 } else { lat.sum_us / lat.steps },
                    max_us: lat.max_us,
                });
            }
        }
        rows.sort_by(|a, b| b.max_us.cmp(&a.max_us).then(a.session.cmp(&b.session)));
        rows.truncate(n);
        rows
    }

    /// Lease `n_envs` slots on the first `task` shard with room and open
    /// a session. Fails when no shard can host the lease — detach other
    /// sessions (freeing their slots) or add shards — or when admitting
    /// it would blow the server's memory budget (see
    /// [`with_budget`](SimServer::with_budget)).
    pub fn connect(&self, task: Task, n_envs: usize) -> Result<Session> {
        self.try_connect(task, n_envs)
            .map_err(|d| anyhow!("{}", d.message()))
    }

    /// [`connect`](SimServer::connect) with a typed decline, so the wire
    /// front door can distinguish overload (shed with retry-after) from
    /// capacity (a plain lease error). Admission-control declines count
    /// in `serve.shed.admission`.
    pub fn try_connect(&self, task: Task, n_envs: usize) -> Result<Session, LeaseDecline> {
        if n_envs == 0 {
            return Err(LeaseDecline::NoCapacity(
                "connect: a session needs at least one env slot".into(),
            ));
        }
        // One admission decision at a time: the activation snapshot below
        // must not race another connect's lease.
        let _admission = self.admission.lock().unwrap();
        // Which shards are active (hold at least one lease)? Their assets
        // are pinned resident; idle shards count only once admitted.
        let active: Vec<bool> = self
            .shards
            .iter()
            .map(|sh| lock_state(&sh.state).coal.leased() > 0)
            .collect();
        let active_bytes: usize = self
            .shards
            .iter()
            .zip(&active)
            .filter(|(_, &a)| a)
            .map(|(sh, _)| sh.resident_bytes)
            .sum();
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        let mut over_budget = None;
        let mut quarantined = 0usize;
        for (shard, &was_active) in self.shards.iter().zip(&active) {
            if shard.task != task {
                continue;
            }
            if let (Some(budget), false) = (self.mem_budget, was_active) {
                let projected = active_bytes + shard.resident_bytes;
                if projected > budget {
                    over_budget = Some(projected);
                    continue;
                }
            }
            let slots = {
                let mut st = lock_state(&shard.state);
                if st.shutdown {
                    if st.quarantined {
                        quarantined += 1;
                    }
                    continue;
                }
                st.coal.lease(id, n_envs)
            };
            if let Some(slots) = slots {
                shard.events.emit(
                    "lease.grant",
                    &[
                        ("session", Json::Num(id as f64)),
                        ("shard", Json::Num(shard.idx as f64)),
                        ("task", Json::Str(format!("{task:?}"))),
                        ("n_envs", Json::Num(n_envs as f64)),
                    ],
                );
                return Ok(Session::open(Arc::clone(shard), id, slots));
            }
        }
        if let (Some(projected), Some(budget)) = (over_budget, self.mem_budget) {
            self.shed_admission.inc();
            return Err(LeaseDecline::Overload(format!(
                "connect: admitting a {n_envs}-env {task:?} lease would put \
                 {} MB of scene assets resident, over the {} MB budget — \
                 detach sessions on other shards or raise --mem-budget",
                projected / (1024 * 1024),
                budget / (1024 * 1024)
            )));
        }
        let quarantine_note = if quarantined > 0 {
            format!(" ({quarantined} quarantined — restart_shard may recover them)")
        } else {
            String::new()
        };
        Err(LeaseDecline::NoCapacity(format!(
            "connect: no {task:?} shard with {n_envs} free slots \
             (tasks served: {:?}){quarantine_note}",
            self.shards.iter().map(|s| s.task).collect::<Vec<_>>()
        )))
    }

    /// Lease `n_envs` slots *plus* the server-side policy `variant`, and
    /// let the server drive them: the returned [`TenantSession`] only
    /// sets goals and streams back trajectories. Greedy actions — see
    /// [`connect_with_policy_mode`](SimServer::connect_with_policy_mode)
    /// for sampled ones. Fails without a vault (no artifacts), for
    /// unknown variants, and when the variant's geometry cannot drive
    /// this shard (obs shape mismatch, or no `infer_n{slots}` artifact —
    /// tenant inference always runs at full shard width).
    pub fn connect_with_policy(
        &self,
        task: Task,
        n_envs: usize,
        variant: &str,
    ) -> Result<TenantSession> {
        self.connect_with_policy_mode(task, n_envs, variant, ActionMode::Greedy)
    }

    /// [`connect_with_policy`](SimServer::connect_with_policy) with an
    /// explicit [`ActionMode`].
    pub fn connect_with_policy_mode(
        &self,
        task: Task,
        n_envs: usize,
        variant_name: &str,
        mode: ActionMode,
    ) -> Result<TenantSession> {
        let r = self.connect_with_policy_inner(task, n_envs, variant_name, mode);
        if let Err(e) = &r {
            self.events.emit(
                "lease.policy_decline",
                &[
                    ("variant", Json::Str(variant_name.to_string())),
                    ("task", Json::Str(format!("{task:?}"))),
                    ("n_envs", Json::Num(n_envs as f64)),
                    ("reason", Json::Str(format!("{e:#}"))),
                ],
            );
        }
        r
    }

    fn connect_with_policy_inner(
        &self,
        task: Task,
        n_envs: usize,
        variant_name: &str,
        mode: ActionMode,
    ) -> Result<TenantSession> {
        let Some(vault) = &self.vault else {
            bail!(
                "connect_with_policy: no policy artifacts on this server — \
                 start it over a directory holding artifacts/manifest.json \
                 (run `make artifacts`), or serve envs only via connect()"
            );
        };
        let variant = vault.variant(variant_name)?;
        let session = self.connect(task, n_envs)?;
        let obs_floats = session.obs_floats();
        if variant.res * variant.res * variant.in_ch != obs_floats {
            bail!(
                "connect_with_policy: variant {variant_name:?} expects \
                 {}x{}x{} observations but the shard renders {obs_floats} \
                 floats per env — serve with --res {}",
                variant.res,
                variant.res,
                variant.in_ch,
                variant.res
            );
        }
        let shard_idx = self
            .shards
            .iter()
            .position(|s| Arc::ptr_eq(s, session.shard()))
            .expect("session maps to a shard");
        let width = self.shards[shard_idx].slots;
        if !variant.infer_ns.contains(&width) {
            bail!(
                "connect_with_policy: tenant inference runs at full shard \
                 width, but variant {variant_name:?} exports no \
                 infer_n{width} artifact (exported: {:?}) — size the shard \
                 to match (--slots) or re-export the preset",
                variant.infer_ns
            );
        }
        // First policy lease on the shard stands up its tenant registry
        // + driver thread.
        let tshared = {
            let mut tenancy = lock_tenancy(&self.tenancy);
            if tenancy[shard_idx].is_none() {
                let straggler = lock_state(&self.shards[shard_idx].state).coal.policy();
                let shared = Arc::new(TenantShared::new(width, straggler));
                {
                    // Attach the tenant registry's cells (same-cell
                    // discipline as the shard coalescer above).
                    let sid = shard_idx.to_string();
                    let l: &[(&str, &str)] = &[("shard", &sid)];
                    let st = lock_tenants(&shared.state);
                    self.registry.attach_counter("tenant.infer_runs", l, &st.infer_runs);
                    self.registry.attach_counter("tenant.agent_steps", l, &st.agent_steps);
                    self.registry.attach_counter("tenant.idle_fills", l, &st.coal.idle_fills);
                    self.registry.attach_gauge("tenant.registered", l, &st.coal.obs_registered);
                    self.registry.attach_gauge("tenant.active", l, &st.coal.obs_active);
                }
                let for_driver = Arc::clone(&shared);
                let shard = Arc::clone(&self.shards[shard_idx]);
                let vault = Arc::clone(vault);
                let hb = self
                    .watchdog
                    .register("tenant-driver", DRIVER_DEGRADED, DRIVER_STALLED);
                // Same supervisor contract as shard drivers: a panic in
                // the tenant driver quarantines this shard's tenancy
                // (handles see the error; env-only sessions unaffected)
                // instead of tearing the process down.
                let sup_shared = Arc::clone(&shared);
                let sup_hb = hb.clone();
                let events = Arc::clone(&self.events);
                let recorder = Arc::clone(&self.recorder);
                let driver = std::thread::Builder::new()
                    .name("sim-serve-tenant".into())
                    .spawn(move || {
                        let r = catch_unwind(AssertUnwindSafe(move || {
                            tenant_driver(for_driver, shard, vault, hb)
                        }));
                        if let Err(e) = r {
                            let msg = format!(
                                "tenant driver panicked: {}",
                                panic_msg(e.as_ref())
                            );
                            quarantine_tenants(&sup_shared, msg.clone());
                            sup_hb.dead();
                            events.emit(
                                "tenant.quarantine",
                                &[
                                    ("shard", Json::Num(shard_idx as f64)),
                                    ("reason", Json::Str(msg.clone())),
                                ],
                            );
                            if let Some(rec) = recorder.get() {
                                let _ = rec.trigger(Trigger::DriverPanic(msg));
                            }
                        }
                    })
                    .map_err(|e| anyhow!("spawn tenant driver thread: {e}"))?;
                self.tenant_drivers
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .push(driver);
                tenancy[shard_idx] = Some(shared);
            }
            Arc::clone(tenancy[shard_idx].as_ref().unwrap())
        };
        let tenant_id = session.id();
        let slots = session.slots().to_vec();
        let v = session.view();
        let initial = TrajStep {
            step: v.step,
            actions: Vec::new(),
            obs: v.obs.to_vec(),
            goal: v.goal.to_vec(),
            rewards: v.rewards.to_vec(),
            dones: v.dones.to_vec(),
            successes: v.successes.to_vec(),
            spl: v.spl.to_vec(),
            scores: v.scores.to_vec(),
        };
        let (tx, rx) = std::sync::mpsc::sync_channel(TRAJ_QUEUE);
        {
            let mut st = lock_tenants(&tshared.state);
            if st.shutdown {
                let msg = st.error.clone().unwrap_or_else(|| "tenant driver stopped".into());
                bail!("connect_with_policy: {msg}");
            }
            st.coal.register(tenant_id);
            st.joins.push(Join {
                tenant: tenant_id,
                session,
                mode,
                variant: variant_name.to_string(),
                tx,
            });
            tshared.posted.notify_all();
        }
        Ok(TenantSession::new(
            TenantControl::new(tshared, tenant_id),
            task,
            obs_floats,
            slots,
            rx,
            initial,
        ))
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Point-in-time stats for every shard: occupancy, queue depth,
    /// step counts, straggler fills, latency percentiles, and — for
    /// shards hosting policy tenants — inference-coalescing counters.
    /// Every counter here is a read of the registry cell a scrape
    /// renders, so the two views agree bitwise at any quiescent instant.
    pub fn stats(&self) -> Vec<ShardStats> {
        let mut out: Vec<ShardStats> = self
            .shards
            .iter()
            .map(|sh| {
                let st = lock_state(&sh.state);
                let [latency_p50, latency_p95] = st.latency.percentiles([0.5, 0.95]);
                ShardStats {
                    task: sh.task,
                    slots: sh.slots,
                    leased: st.coal.leased(),
                    queued_actions: st.coal.pending(),
                    steps: sh.obs.steps.get(),
                    straggler_fills: st.coal.straggler_fills.get(),
                    bad_submits: st.coal.bad_submits.get(),
                    rotations: sh.rotations.load(Ordering::Relaxed),
                    resident_bytes: sh.resident_bytes,
                    latency_p50,
                    latency_p95,
                    tenant: None,
                }
            })
            .collect();
        let tenancy = lock_tenancy(&self.tenancy);
        for (stats, tshared) in out.iter_mut().zip(tenancy.iter()) {
            let Some(ts) = tshared else { continue };
            let st = lock_tenants(&ts.state);
            let [infer_p50, infer_p95] = st.infer_lat.percentiles([0.5, 0.95]);
            let [gather_p50, gather_p95] = st.gather_lat.percentiles([0.5, 0.95]);
            let [step_p50, step_p95] = st.step_lat.percentiles([0.5, 0.95]);
            stats.tenant = Some(TenantStats {
                tenants: st.coal.registered(),
                agent_steps: st.agent_steps.get(),
                infer_runs: st.infer_runs.get(),
                infer_batch_size: ts.width,
                idle_fills: st.coal.idle_fills.get(),
                infer_p50,
                infer_p95,
                gather_p50,
                gather_p95,
                step_p50,
                step_p95,
            });
        }
        out
    }
}

impl Drop for SimServer {
    fn drop(&mut self) {
        // Watchdog first: otherwise the joins below read as silence and
        // a shutdown would log spurious stall events.
        self.watchdog.stop();
        // Shards first: a tenant driver blocked in a ticket wait (e.g. a
        // Wait-policy co-tenant never submitted) unblocks with an error
        // once its shard fails; then the tenant drivers can be joined
        // before the shard threads are.
        for sh in &self.shards {
            sh.fail("server shut down".into());
        }
        for ts in lock_tenancy(&self.tenancy).iter().flatten() {
            let mut st = lock_tenants(&ts.state);
            st.shutdown = true;
            ts.posted.notify_all();
        }
        let tenant_drivers: Vec<JoinHandle<()>> = self
            .tenant_drivers
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .drain(..)
            .collect();
        for d in tenant_drivers {
            let _ = d.join();
        }
        let drivers: Vec<JoinHandle<()>> = self
            .drivers
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .drain(..)
            .collect();
        for d in drivers {
            let _ = d.join();
        }
    }
}
