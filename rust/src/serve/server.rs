//! [`SimServer`]: N `EnvBatch` shards behind a session front door.
//!
//! Each shard is one `EnvBatch` owned by a dedicated **shard driver
//! thread**; all shards share one `WorkerPool`, so the machine's cores are
//! scheduled across shards exactly as they are across a single big batch.
//! Clients never see the batch: [`SimServer::connect`] leases env slots
//! and returns a [`Session`](super::Session), and the shard's
//! [`Coalescer`] assembles full batch steps from the sessions' partial
//! submissions. Results are published as shared snapshots
//! ([`StepResult`]) that sessions slice into per-client views, so one
//! `EnvBatch::submit` serves every tenant of the shard.
//!
//! Synchronization is a mutex + two condvars per shard: `submitted`
//! (clients → driver: actions arrived / leases changed) and `stepped`
//! (driver → clients: the published step advanced). The driver recycles
//! `StepResult` buffers through `Arc::try_unwrap`, so the steady-state
//! serving loop allocates nothing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::env::{EnvBatch, EnvBatchConfig, StepView};
use crate::metrics::Window;
use crate::render::SceneRotation;
use crate::scene::SceneAsset;
use crate::sim::Task;
use crate::util::pool::WorkerPool;

use super::coalescer::{Coalescer, StragglerPolicy};
use super::session::Session;

/// Driver wakeup granularity while waiting out a straggler deadline
/// (`StragglerPolicy::Deadline { ticks, .. }` waits `ticks` of these).
pub const TICK: Duration = Duration::from_millis(1);

/// How many latency samples the per-shard window keeps for p50/p95.
const LATENCY_WINDOW: usize = 4096;

/// One completed batch step, published to every session of a shard.
/// Same SoA shape as [`StepView`], but owned, so tenants on other
/// threads can hold it while the `EnvBatch` reuses its step buffers.
#[derive(Default)]
pub(crate) struct StepResult {
    pub step: u64,
    pub obs: Vec<f32>,
    pub goal: Vec<f32>,
    pub rewards: Vec<f32>,
    pub dones: Vec<bool>,
    pub successes: Vec<bool>,
    pub spl: Vec<f32>,
    pub scores: Vec<f32>,
}

impl StepResult {
    /// Copy a step's view in, reusing this result's buffers.
    fn fill(&mut self, step: u64, v: StepView<'_>) {
        self.step = step;
        self.obs.clear();
        self.obs.extend_from_slice(v.obs);
        self.goal.clear();
        self.goal.extend_from_slice(v.goal);
        self.rewards.clear();
        self.rewards.extend_from_slice(v.rewards);
        self.dones.clear();
        self.dones.extend_from_slice(v.dones);
        self.successes.clear();
        self.successes.extend_from_slice(v.successes);
        self.spl.clear();
        self.spl.extend_from_slice(v.spl);
        self.scores.clear();
        self.scores.extend_from_slice(v.scores);
    }
}

/// Mutex-guarded per-shard state (lease table + published step).
pub(crate) struct ShardState {
    pub coal: Coalescer,
    /// Latest completed step (`result.step` steps have fully executed).
    pub result: Arc<StepResult>,
    /// Steps handed to the `EnvBatch` so far; a submit buffered now is
    /// consumed by step `issued + 1`, which is what tickets wait for.
    pub issued: u64,
    pub shutdown: bool,
    pub error: Option<String>,
    /// Shard-wide submit→result latency samples (seconds).
    pub latency: Window,
}

/// One shard as seen by sessions and the driver thread.
pub(crate) struct ShardShared {
    pub task: Task,
    pub slots: usize,
    pub obs_floats: usize,
    pub state: Mutex<ShardState>,
    /// Clients → driver: actions buffered / leases changed / shutdown.
    pub submitted: Condvar,
    /// Driver → clients: `state.result` advanced (or shard failed).
    pub stepped: Condvar,
}

impl ShardShared {
    pub fn fail(&self, msg: String) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        st.error = Some(msg);
        self.submitted.notify_all();
        self.stepped.notify_all();
    }
}

/// The shard driver loop: coalesce → step → publish, until shutdown.
fn shard_driver(shared: Arc<ShardShared>, mut env: EnvBatch) {
    let mut actions: Vec<u8> = Vec::with_capacity(shared.slots);
    let mut spare: Option<StepResult> = None;
    loop {
        // Phase 1: wait until a full batch can be assembled.
        let step_no = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.coal.ready() {
                    break;
                }
                match st.coal.policy() {
                    StragglerPolicy::Deadline { ticks, .. } if st.coal.has_pending() => {
                        if st.coal.waited() >= ticks {
                            break; // deadline passed: fill stragglers
                        }
                        let (guard, timeout) = shared.submitted.wait_timeout(st, TICK).unwrap();
                        st = guard;
                        if timeout.timed_out() {
                            st.coal.tick();
                        }
                    }
                    _ => st = shared.submitted.wait(st).unwrap(),
                }
            }
            st.coal.assemble(&mut actions);
            st.issued += 1;
            st.issued
        };
        // Phase 2: step the batch outside the lock (sim + render).
        let result = match env.step(&actions) {
            Ok(view) => {
                let mut r = spare.take().unwrap_or_default();
                r.fill(step_no, view);
                Arc::new(r)
            }
            Err(e) => {
                shared.fail(format!("shard step failed: {e:#}"));
                return;
            }
        };
        // Phase 3: publish, then reclaim the old snapshot's buffers if no
        // session still holds it.
        let prev = {
            let mut st = shared.state.lock().unwrap();
            let prev = std::mem::replace(&mut st.result, result);
            shared.stepped.notify_all();
            prev
        };
        if let Ok(r) = Arc::try_unwrap(prev) {
            spare = Some(r);
        }
    }
}

/// Where a shard's environments get their scenes (mirrors the two
/// [`EnvBatchConfig`] build paths).
pub enum SceneSource {
    /// Explicit env → scene assignment; the batch size is `scenes.len()`.
    Scenes(Vec<Arc<SceneAsset>>),
    /// `n` envs over a K-slot rotation. The serve layer does not drive
    /// `rotate_scenes` yet — the rotation provides the initial residency.
    Rotation { rotation: SceneRotation, n: usize },
}

/// Everything needed to stand up one shard of a [`SimServer`].
pub struct ShardSpec {
    pub cfg: EnvBatchConfig,
    pub source: SceneSource,
    pub straggler: StragglerPolicy,
}

impl ShardSpec {
    /// A shard over an explicit scene assignment, defaulting to the
    /// deterministic `Wait` coalescing policy.
    pub fn with_scenes(cfg: EnvBatchConfig, scenes: Vec<Arc<SceneAsset>>) -> ShardSpec {
        ShardSpec {
            cfg,
            source: SceneSource::Scenes(scenes),
            straggler: StragglerPolicy::Wait,
        }
    }

    /// A shard of `n` envs over a K-slot scene rotation.
    pub fn with_rotation(cfg: EnvBatchConfig, rotation: SceneRotation, n: usize) -> ShardSpec {
        ShardSpec {
            cfg,
            source: SceneSource::Rotation { rotation, n },
            straggler: StragglerPolicy::Wait,
        }
    }

    /// Override the straggler policy for this shard's coalescer.
    pub fn straggler(mut self, policy: StragglerPolicy) -> ShardSpec {
        self.straggler = policy;
        self
    }
}

/// Point-in-time counters for one shard (see [`SimServer::stats`]).
#[derive(Clone, Debug)]
pub struct ShardStats {
    pub task: Task,
    /// Total env slots in the shard.
    pub slots: usize,
    /// Slots currently leased to sessions (occupancy numerator).
    pub leased: usize,
    /// Actions buffered in the coalescer awaiting the next step.
    pub queued_actions: usize,
    /// Batch steps completed since start.
    pub steps: u64,
    /// Leased slots the straggler policy had to fill, cumulative.
    pub straggler_fills: u64,
    /// Submit→result latency percentiles over recent steps (seconds).
    pub latency_p50: f32,
    pub latency_p95: f32,
}

impl ShardStats {
    /// Leased fraction of the shard's slots.
    pub fn occupancy(&self) -> f32 {
        if self.slots == 0 {
            return 0.0;
        }
        self.leased as f32 / self.slots as f32
    }
}

/// The multi-tenant simulation server (see module docs).
pub struct SimServer {
    shards: Vec<Arc<ShardShared>>,
    drivers: Vec<JoinHandle<()>>,
    next_session: AtomicU64,
}

impl SimServer {
    /// Build every shard's `EnvBatch` and start one driver thread per
    /// shard. Shards may be heterogeneous (different tasks / render
    /// configs); they share `pool`.
    pub fn start(specs: Vec<ShardSpec>, pool: Arc<WorkerPool>) -> Result<SimServer> {
        if specs.is_empty() {
            bail!("SimServer needs at least one shard");
        }
        let mut shards = Vec::with_capacity(specs.len());
        let mut drivers = Vec::with_capacity(specs.len());
        for spec in specs {
            let ShardSpec {
                cfg,
                source,
                straggler,
            } = spec;
            // The shard driver always submits and immediately waits, so
            // the EnvBatch's own pipelined driver thread would add a
            // channel round-trip per step with zero overlap benefit:
            // force the (bitwise-identical) synchronous path.
            let cfg = cfg.overlap(false);
            let env = match source {
                SceneSource::Scenes(scenes) => cfg.build_with_scenes(scenes, Arc::clone(&pool))?,
                SceneSource::Rotation { rotation, n } => {
                    cfg.build_with_rotation(rotation, n, Arc::clone(&pool))?
                }
            };
            let slots = env.num_envs();
            // Publish the initial observation as step 0 so sessions can
            // read a view before their first submit.
            let mut initial = StepResult::default();
            initial.fill(0, env.view());
            let shared = Arc::new(ShardShared {
                task: env.task(),
                slots,
                obs_floats: env.obs_floats(),
                state: Mutex::new(ShardState {
                    coal: Coalescer::new(slots, straggler),
                    result: Arc::new(initial),
                    issued: 0,
                    shutdown: false,
                    error: None,
                    latency: Window::new(LATENCY_WINDOW),
                }),
                submitted: Condvar::new(),
                stepped: Condvar::new(),
            });
            let for_driver = Arc::clone(&shared);
            let driver = std::thread::Builder::new()
                .name("sim-serve-shard".into())
                .spawn(move || shard_driver(for_driver, env))
                .map_err(|e| anyhow!("spawn shard driver thread: {e}"))?;
            shards.push(shared);
            drivers.push(driver);
        }
        Ok(SimServer {
            shards,
            drivers,
            next_session: AtomicU64::new(1),
        })
    }

    /// Lease `n_envs` slots on the first `task` shard with room and open
    /// a session. Fails when no shard can host the lease — detach other
    /// sessions (freeing their slots) or add shards.
    pub fn connect(&self, task: Task, n_envs: usize) -> Result<Session> {
        if n_envs == 0 {
            bail!("connect: a session needs at least one env slot");
        }
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        for shard in &self.shards {
            if shard.task != task {
                continue;
            }
            let slots = {
                let mut st = shard.state.lock().unwrap();
                if st.shutdown {
                    continue;
                }
                st.coal.lease(id, n_envs)
            };
            if let Some(slots) = slots {
                return Ok(Session::open(Arc::clone(shard), id, slots));
            }
        }
        bail!(
            "connect: no {task:?} shard with {n_envs} free slots \
             (tasks served: {:?})",
            self.shards.iter().map(|s| s.task).collect::<Vec<_>>()
        )
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Point-in-time stats for every shard: occupancy, queue depth,
    /// step counts, straggler fills, and latency percentiles.
    pub fn stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .map(|sh| {
                let st = sh.state.lock().unwrap();
                ShardStats {
                    task: sh.task,
                    slots: sh.slots,
                    leased: st.coal.leased(),
                    queued_actions: st.coal.pending(),
                    steps: st.result.step,
                    straggler_fills: st.coal.straggler_fills,
                    latency_p50: st.latency.percentile(0.5),
                    latency_p95: st.latency.percentile(0.95),
                }
            })
            .collect()
    }
}

impl Drop for SimServer {
    fn drop(&mut self) {
        for sh in &self.shards {
            sh.fail("server shut down".into());
        }
        for d in self.drivers.drain(..) {
            let _ = d.join();
        }
    }
}
