//! [`Session`]: one client's lease on a shard, plus the [`Ticket`] /
//! [`SessionView`] request-response cycle.
//!
//! A session owns a set of env slots on one shard (granted by
//! [`SimServer::connect`](super::SimServer::connect)) and mirrors the
//! `EnvBatch` surface at the lease's granularity: `submit(actions)`
//! buffers one action per leased slot and returns a [`Ticket`];
//! `Ticket::wait` blocks until the shard's coalesced batch step that
//! consumed those actions completes, then returns a [`SessionView`] of
//! the session's slice of the step. The slice lives in session-owned SoA
//! buffers (gathered from the shard's published snapshot), so co-tenants
//! never contend after the gather.
//!
//! Sessions are `Send`: connect on one thread, drive from another. Drop
//! (or [`detach`](Session::detach)) frees the slots for re-lease without
//! disturbing co-tenants — freed slots step with `ACTION_STOP`, ending
//! any orphaned episode so the next tenant starts fresh.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::metrics::Window;
use crate::sim::Task;

use super::server::{lock_state, ShardShared, StepResult};

/// How many latency samples each session keeps for its own p50/p95.
const SESSION_LATENCY_WINDOW: usize = 1024;

/// A client's lease of env slots on one shard (see module docs).
pub struct Session {
    shard: Arc<ShardShared>,
    id: u64,
    /// Leased slot indices on the shard, in view order.
    slots: Vec<usize>,
    // Session-local SoA buffers, gathered from the shard snapshot.
    obs: Vec<f32>,
    goal: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    successes: Vec<bool>,
    spl: Vec<f32>,
    scores: Vec<f32>,
    /// Shard step the buffers were last gathered from.
    synced: u64,
    latency: Window,
    detached: bool,
}

impl Session {
    pub(crate) fn open(shard: Arc<ShardShared>, id: u64, slots: Vec<usize>) -> Session {
        let n = slots.len();
        let obs_floats = shard.obs_floats;
        let mut s = Session {
            shard,
            id,
            slots,
            obs: vec![0.0; n * obs_floats],
            goal: vec![0.0; n * 3],
            rewards: vec![0.0; n],
            dones: vec![false; n],
            successes: vec![false; n],
            spl: vec![0.0; n],
            scores: vec![0.0; n],
            synced: 0,
            latency: Window::new(SESSION_LATENCY_WINDOW),
            detached: false,
        };
        // Seed the buffers from the latest published step so `view` works
        // before the first submit.
        let res = Arc::clone(&lock_state(&s.shard.state).result);
        s.gather(&res);
        s
    }

    /// Whether the shard backing this lease is quarantined after a
    /// driver panic (the wire pump maps this to a retry-after-hinted
    /// `SHARD_DOWN` error frame instead of a generic shard error).
    pub fn shard_quarantined(&self) -> bool {
        lock_state(&self.shard.state).quarantined
    }

    /// Envs leased by this session.
    pub fn num_envs(&self) -> usize {
        self.slots.len()
    }

    /// The shard backing this lease (tenant-driver plumbing).
    pub(crate) fn shard(&self) -> &Arc<ShardShared> {
        &self.shard
    }

    /// This session's lease id on the shard coalescer.
    pub(crate) fn id(&self) -> u64 {
        self.id
    }

    /// Floats per env observation tile (shard render config).
    pub fn obs_floats(&self) -> usize {
        self.shard.obs_floats
    }

    pub fn task(&self) -> Task {
        self.shard.task
    }

    /// The shard slot indices backing this lease (ascending).
    pub fn slots(&self) -> &[usize] {
        &self.slots
    }

    /// This session's view of the last step it gathered (initially the
    /// shard's latest published observation).
    pub fn view(&self) -> SessionView<'_> {
        SessionView {
            step: self.synced,
            obs: &self.obs,
            goal: &self.goal,
            rewards: &self.rewards,
            dones: &self.dones,
            successes: &self.successes,
            spl: &self.spl,
            scores: &self.scores,
        }
    }

    /// Submit one action per leased slot (`actions[j]` steps
    /// `self.slots()[j]`). Returns a [`Ticket`] for the coalesced batch
    /// step that will consume them; the shard steps once every leased
    /// slot has an action (or the straggler deadline fires).
    pub fn submit(&mut self, actions: &[u8]) -> Result<Ticket<'_>> {
        if self.detached {
            bail!("submit on a detached session");
        }
        if actions.len() != self.slots.len() {
            bail!(
                "submit: {} actions for a {}-env session",
                actions.len(),
                self.slots.len()
            );
        }
        let target = {
            let mut st = lock_state(&self.shard.state);
            if st.shutdown {
                let msg = st.error.clone().unwrap_or_else(|| "shard stopped".into());
                bail!("serve: {msg}");
            }
            st.coal.submit(self.id, &self.slots, actions);
            // Wake the driver: the batch may now be complete, and a
            // deadline-policy driver must notice the first pending action.
            self.shard.submitted.notify_all();
            st.issued + 1
        };
        Ok(Ticket {
            session: self,
            target,
            submitted: Instant::now(),
        })
    }

    /// Wire-transport submit: buffer `actions[j]` for *shard-absolute*
    /// slot index `slots[j]`, which arrives off the wire and is therefore
    /// untrusted — out-of-range, unleased, or foreign slots are skipped
    /// by the coalescer (counted in the shard's `bad_submits`) instead of
    /// panicking the driver. Returns the number of accepted submissions
    /// plus the [`Ticket`] for the step that will consume them; with
    /// `accepted == 0` nothing was buffered, so the caller should *not*
    /// wait on the ticket (the step it names may never be provoked).
    pub(crate) fn submit_at(
        &mut self,
        slots: &[usize],
        actions: &[u8],
    ) -> Result<(usize, Ticket<'_>)> {
        if self.detached {
            bail!("submit on a detached session");
        }
        if slots.len() != actions.len() {
            bail!(
                "submit_at: {} slots for {} actions",
                slots.len(),
                actions.len()
            );
        }
        let (accepted, target) = {
            let mut st = lock_state(&self.shard.state);
            if st.shutdown {
                let msg = st.error.clone().unwrap_or_else(|| "shard stopped".into());
                bail!("serve: {msg}");
            }
            let accepted = st.coal.submit(self.id, slots, actions);
            if accepted > 0 {
                self.shard.submitted.notify_all();
            }
            (accepted, st.issued + 1)
        };
        Ok((
            accepted,
            Ticket {
                session: self,
                target,
                submitted: Instant::now(),
            },
        ))
    }

    /// Convenience: submit and immediately wait.
    pub fn step(&mut self, actions: &[u8]) -> Result<SessionView<'_>> {
        self.submit(actions)?.wait()
    }

    /// Free this session's slots for re-lease. Co-tenants are not
    /// disturbed: the shard keeps stepping, with the freed slots on the
    /// auto-reset filler. Idempotent; also runs on drop.
    pub fn detach(&mut self) {
        if self.detached {
            return;
        }
        self.detached = true;
        {
            let mut st = lock_state(&self.shard.state);
            st.coal.release(self.id);
            // A waiting driver may now have a complete batch (every
            // remaining leased slot already submitted).
            self.shard.submitted.notify_all();
        }
        self.shard.events.emit(
            "lease.release",
            &[
                ("session", crate::util::json::Json::Num(self.id as f64)),
                ("shard", crate::util::json::Json::Num(self.shard.idx as f64)),
            ],
        );
    }

    /// Submit→result latency percentiles (p50, p95) over this session's
    /// recent steps, in seconds.
    pub fn latency(&self) -> (f32, f32) {
        let [p50, p95] = self.latency.percentiles([0.5, 0.95]);
        (p50, p95)
    }

    /// Copy this session's slots out of a published shard snapshot.
    fn gather(&mut self, res: &StepResult) {
        let of = self.shard.obs_floats;
        for (j, &slot) in self.slots.iter().enumerate() {
            self.obs[j * of..(j + 1) * of]
                .copy_from_slice(&res.obs[slot * of..(slot + 1) * of]);
            self.goal[j * 3..j * 3 + 3].copy_from_slice(&res.goal[slot * 3..slot * 3 + 3]);
            self.rewards[j] = res.rewards[slot];
            self.dones[j] = res.dones[slot];
            self.successes[j] = res.successes[slot];
            self.spl[j] = res.spl[slot];
            self.scores[j] = res.scores[slot];
        }
        self.synced = res.step;
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.detach();
    }
}

/// An in-flight session step: the shard batch step that will consume
/// this session's submitted actions. [`wait`](Ticket::wait) blocks until
/// it completes; meanwhile [`current`](Ticket::current) still serves the
/// previous step for overlapped bookkeeping, mirroring
/// `StepHandle::current`.
pub struct Ticket<'a> {
    session: &'a mut Session,
    target: u64,
    submitted: Instant,
}

impl<'a> Ticket<'a> {
    /// The shard step this ticket resolves at.
    pub fn step(&self) -> u64 {
        self.target
    }

    /// The session's previous gathered view (valid while the coalesced
    /// step executes).
    pub fn current(&self) -> SessionView<'_> {
        self.session.view()
    }

    /// Block until the coalesced batch step completes, gather this
    /// session's slice, and view it.
    ///
    /// Latest-wins semantics: the view reflects the shard's most recent
    /// published step at wake-up time, which under a
    /// [`Deadline`](super::StragglerPolicy::Deadline) policy can be
    /// *later* than [`step`](Ticket::step) — if this client stalls, the
    /// deadline keeps its slots stepping and intermediate snapshots are
    /// not retained. Compare `view.step` against `ticket.step()` when
    /// per-step accounting matters; with the `Wait` policy they always
    /// match.
    pub fn wait(self) -> Result<SessionView<'a>> {
        let Ticket {
            session,
            target,
            submitted,
        } = self;
        let shard = Arc::clone(&session.shard);
        let res = {
            let mut st = lock_state(&shard.state);
            while st.result.step < target {
                if st.shutdown {
                    let msg = st.error.clone().unwrap_or_else(|| "shard stopped".into());
                    bail!("serve: {msg}");
                }
                st = shard.stepped.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            let elapsed = submitted.elapsed();
            let lat = elapsed.as_secs_f32();
            st.latency.push(lat);
            session.latency.push(lat);
            let elapsed_us = elapsed.as_micros() as u64;
            shard.obs.latency_us.observe(elapsed_us);
            // Latency attribution: split the end-to-end wait into the
            // driver-measured phases of the step that resolved it, with
            // coalesce-wait as the residual — the four phases sum to the
            // e2e latency by construction.
            let r = &st.result;
            let known = r.sim_us + r.render_us + r.publish_us;
            shard.phase.sim.observe(r.sim_us);
            shard.phase.render.observe(r.render_us);
            shard.phase.publish.observe(r.publish_us);
            shard.phase.coalesce.observe(elapsed_us.saturating_sub(known));
            // Slowest-sessions table row (capped; cheapest row evicted).
            if !st.sess_lat.contains_key(&session.id)
                && st.sess_lat.len() >= super::server::SESS_LAT_CAP
            {
                let evict = st
                    .sess_lat
                    .iter()
                    .min_by_key(|(_, v)| v.max_us)
                    .map(|(&k, _)| k);
                if let Some(k) = evict {
                    st.sess_lat.remove(&k);
                }
            }
            let row = st.sess_lat.entry(session.id).or_default();
            row.steps += 1;
            row.sum_us += elapsed_us;
            row.max_us = row.max_us.max(elapsed_us);
            Arc::clone(&st.result)
        };
        session.gather(&res);
        Ok(session.view())
    }
}

/// Borrowed SoA results of one session step: the same shape as
/// `env::StepView`, restricted to the session's leased slots, plus the
/// shard step counter it was gathered from.
#[derive(Clone, Copy)]
pub struct SessionView<'a> {
    /// Shard batch step these results belong to.
    pub step: u64,
    pub obs: &'a [f32],
    pub goal: &'a [f32],
    pub rewards: &'a [f32],
    pub dones: &'a [bool],
    pub successes: &'a [bool],
    pub spl: &'a [f32],
    pub scores: &'a [f32],
}
