//! Unified fault-injection plane (DESIGN.md §0.12): a spec-driven
//! [`Injector`] that exercises every recovery path in the serving layer
//! deterministically.
//!
//! The spec grammar is a comma-separated clause list, each clause
//! `name[:key=value[:key=value]]`:
//!
//! ```text
//! conn_drop:p=0.01        drop a connection with probability p per
//! conn_drop:every=50        outbound frame — or every Nth frame exactly
//! panic:shard=0           panic the named shard driver at its next
//!                           step (one-shot; repeatable per shard)
//! delay_write:ms=50       sleep before every outbound frame write
//! corrupt:p=0.001         corrupt an outbound frame's header with
//! corrupt:every=100         probability p — or every Nth frame
//! stall:role=NAME         pin the watchdog role stalled (repeatable;
//!                           `role` may itself be a comma-free name)
//! seed=1234               seed the injector RNG (default 0xFA417)
//! ```
//!
//! The spec arrives via `bps serve --fault SPEC` or the `BPS_FAULT`
//! environment variable. The legacy `BPS_FAULT_STALL` variable folds in
//! as extra `stall` clauses and now accepts a comma-separated role list
//! ([`FaultSpec::add_stall_roles`]).
//!
//! All randomized decisions come from one seeded xoshiro [`Rng`], so a
//! chaos run is reproducible: the same spec against the same traffic
//! sequence injects the same faults. `every=N` clauses are fully
//! deterministic counters for tests that must know the exact fault
//! count.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::Rng;

/// Default injector seed when the spec has no `seed=` clause.
const DEFAULT_SEED: u64 = 0xFA417;

/// How often a probabilistic fault fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Rate {
    /// Bernoulli per decision point, from the injector's seeded RNG.
    P(f32),
    /// Exactly every Nth decision point (deterministic).
    Every(u64),
}

/// Parsed fault spec (see module docs for the grammar).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultSpec {
    pub seed: Option<u64>,
    pub conn_drop: Option<Rate>,
    /// Shard indices whose driver panics at its next step (one-shot
    /// each). Duplicates are allowed: each entry arms one panic.
    pub panic_shards: Vec<usize>,
    pub delay_write: Option<Duration>,
    pub corrupt: Option<Rate>,
    /// Watchdog roles pinned stalled (the `BPS_FAULT_STALL` plane).
    pub stall_roles: Vec<String>,
}

fn parse_rate(key: &str, val: &str) -> Result<Rate> {
    match key {
        "p" => {
            let p: f32 = val.parse().with_context(|| format!("bad p={val}"))?;
            if !(0.0..=1.0).contains(&p) {
                bail!("p={p} out of [0,1]");
            }
            Ok(Rate::P(p))
        }
        "every" => {
            let n: u64 = val.parse().with_context(|| format!("bad every={val}"))?;
            if n == 0 {
                bail!("every=0 is meaningless");
            }
            Ok(Rate::Every(n))
        }
        _ => bail!("unknown rate key {key:?} (want p= or every=)"),
    }
}

impl FaultSpec {
    /// Parse the spec grammar. An empty string parses to the empty spec.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec::default();
        for clause in s.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let mut parts = clause.split(':');
            let name = parts.next().unwrap_or("");
            // `seed=N` is a bare key=value clause, not a fault name.
            if let Some(v) = name.strip_prefix("seed=") {
                spec.seed =
                    Some(v.parse().with_context(|| format!("bad seed in {clause:?}"))?);
                continue;
            }
            let mut kv = |part: &str| -> Result<(String, String)> {
                let (k, v) = part
                    .split_once('=')
                    .with_context(|| format!("want key=value in {clause:?}"))?;
                Ok((k.trim().to_owned(), v.trim().to_owned()))
            };
            match name {
                "conn_drop" | "corrupt" => {
                    let part = parts
                        .next()
                        .with_context(|| format!("{name} needs p= or every= ({clause:?})"))?;
                    let (k, v) = kv(part)?;
                    let rate = parse_rate(&k, &v)?;
                    if name == "conn_drop" {
                        spec.conn_drop = Some(rate);
                    } else {
                        spec.corrupt = Some(rate);
                    }
                }
                "panic" => {
                    let part = parts
                        .next()
                        .with_context(|| format!("panic needs shard= ({clause:?})"))?;
                    let (k, v) = kv(part)?;
                    if k != "shard" {
                        bail!("panic wants shard=IDX, got {k}=");
                    }
                    spec.panic_shards
                        .push(v.parse().with_context(|| format!("bad shard in {clause:?}"))?);
                }
                "delay_write" => {
                    let part = parts
                        .next()
                        .with_context(|| format!("delay_write needs ms= ({clause:?})"))?;
                    let (k, v) = kv(part)?;
                    if k != "ms" {
                        bail!("delay_write wants ms=N, got {k}=");
                    }
                    let ms: u64 = v.parse().with_context(|| format!("bad ms in {clause:?}"))?;
                    spec.delay_write = Some(Duration::from_millis(ms));
                }
                "stall" => {
                    let part = parts
                        .next()
                        .with_context(|| format!("stall needs role= ({clause:?})"))?;
                    let (k, v) = kv(part)?;
                    if k != "role" {
                        bail!("stall wants role=NAME, got {k}=");
                    }
                    spec.stall_roles.push(v);
                }
                other => bail!("unknown fault clause {other:?}"),
            }
            if let Some(extra) = parts.next() {
                bail!("trailing {extra:?} in clause {clause:?}");
            }
        }
        Ok(spec)
    }

    /// Fold in a `BPS_FAULT_STALL`-style comma-separated role list (the
    /// legacy env var, kept as an alias for `stall:role=` clauses).
    pub fn add_stall_roles(&mut self, roles: &str) {
        for role in roles.split(',').map(str::trim).filter(|r| !r.is_empty()) {
            if !self.stall_roles.iter().any(|r| r == role) {
                self.stall_roles.push(role.to_owned());
            }
        }
    }

    /// Compact one-line rendering of the armed clauses, for the serve
    /// startup banner. Round-trips through the grammar (modulo clause
    /// order) so the printed string is itself a valid `--fault` spec.
    pub fn describe(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        let rate = |r: &Rate| match r {
            Rate::P(p) => format!("p={p}"),
            Rate::Every(n) => format!("every={n}"),
        };
        if let Some(r) = &self.conn_drop {
            parts.push(format!("conn_drop:{}", rate(r)));
        }
        for s in &self.panic_shards {
            parts.push(format!("panic:shard={s}"));
        }
        if let Some(d) = self.delay_write {
            parts.push(format!("delay_write:ms={}", d.as_millis()));
        }
        if let Some(r) = &self.corrupt {
            parts.push(format!("corrupt:{}", rate(r)));
        }
        for role in &self.stall_roles {
            parts.push(format!("stall:role={role}"));
        }
        if let Some(seed) = self.seed {
            parts.push(format!("seed={seed}"));
        }
        parts.join(",")
    }

    /// True when no clause was given (the injector would be inert).
    pub fn is_empty(&self) -> bool {
        self.conn_drop.is_none()
            && self.panic_shards.is_empty()
            && self.delay_write.is_none()
            && self.corrupt.is_none()
            && self.stall_roles.is_empty()
    }
}

/// One `Rate`'s decision state: a deterministic counter for `Every`,
/// the shared RNG for `P`.
#[derive(Default)]
struct RateState {
    count: u64,
}

impl RateState {
    fn fires(&mut self, rate: Rate, rng: &mut Rng) -> bool {
        match rate {
            Rate::P(p) => rng.chance(p),
            Rate::Every(n) => {
                self.count += 1;
                self.count % n == 0
            }
        }
    }
}

/// The armed fault plane. Shared (`Arc`) between the wire server's
/// writer loops (conn_drop / delay_write / corrupt) and the shard
/// drivers (panic); all methods take `&self`.
pub struct Injector {
    spec: FaultSpec,
    rng: Mutex<Rng>,
    drop_state: Mutex<RateState>,
    corrupt_state: Mutex<RateState>,
    /// Armed one-shot panics; `take_panic` consumes matching entries.
    panics: Mutex<Vec<usize>>,
    /// Faults actually fired, for logs/tests.
    pub fired_drops: AtomicU64,
    pub fired_corrupts: AtomicU64,
    pub fired_panics: AtomicU64,
}

impl Injector {
    pub fn new(spec: FaultSpec) -> Injector {
        let seed = spec.seed.unwrap_or(DEFAULT_SEED);
        let panics = spec.panic_shards.clone();
        Injector {
            spec,
            rng: Mutex::new(Rng::new(seed)),
            drop_state: Mutex::new(RateState::default()),
            corrupt_state: Mutex::new(RateState::default()),
            panics: Mutex::new(panics),
            fired_drops: AtomicU64::new(0),
            fired_corrupts: AtomicU64::new(0),
            fired_panics: AtomicU64::new(0),
        }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Decision point: should this connection be dropped now? Called
    /// once per outbound frame by the wire writer.
    pub fn should_drop_conn(&self) -> bool {
        let Some(rate) = self.spec.conn_drop else {
            return false;
        };
        let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
        let fired = self
            .drop_state
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .fires(rate, &mut rng);
        if fired {
            self.fired_drops.fetch_add(1, Ordering::Relaxed);
        }
        fired
    }

    /// Sleep to impose before an outbound frame write, if any.
    pub fn write_delay(&self) -> Option<Duration> {
        self.spec.delay_write
    }

    /// Decision point: corrupt this outbound frame? When it fires the
    /// frame's magic bytes are flipped in place, which every client
    /// rejects at the header check ([`super::frame::WireError::BadMagic`])
    /// and counts — corruption is always *detectable*, never a silent
    /// payload mutation.
    pub fn corrupt_frame(&self, buf: &mut [u8]) -> bool {
        let Some(rate) = self.spec.corrupt else {
            return false;
        };
        let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
        let fired = self
            .corrupt_state
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .fires(rate, &mut rng);
        if fired && buf.len() >= 2 {
            buf[0] ^= 0xFF;
            buf[1] ^= 0xFF;
            self.fired_corrupts.fetch_add(1, Ordering::Relaxed);
        }
        fired
    }

    /// Consume one armed panic for `shard`, if any. The shard driver
    /// polls this at the top of its step loop and panics when it
    /// returns true — exercising the quarantine path end to end.
    pub fn take_panic(&self, shard: usize) -> bool {
        let mut p = self.panics.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(i) = p.iter().position(|&s| s == shard) {
            p.swap_remove(i);
            self.fired_panics.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Re-arm a one-shot panic at runtime (tests panic a shard while a
    /// session is mid-stream without restarting the server).
    pub fn arm_panic(&self, shard: usize) {
        self.panics
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(shard);
    }

    /// Watchdog roles to pin stalled at startup.
    pub fn stall_roles(&self) -> &[String] {
        &self.spec.stall_roles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example_spec() {
        let s = FaultSpec::parse("conn_drop:p=0.01,panic:shard=0,delay_write:ms=50").unwrap();
        assert_eq!(s.conn_drop, Some(Rate::P(0.01)));
        assert_eq!(s.panic_shards, vec![0]);
        assert_eq!(s.delay_write, Some(Duration::from_millis(50)));
        assert!(s.corrupt.is_none() && s.stall_roles.is_empty());
        assert!(!s.is_empty());
    }

    #[test]
    fn parses_every_seed_corrupt_and_stall() {
        let s =
            FaultSpec::parse("corrupt:every=100,seed=7,stall:role=sim-serve-shard,conn_drop:every=3")
                .unwrap();
        assert_eq!(s.corrupt, Some(Rate::Every(100)));
        assert_eq!(s.seed, Some(7));
        assert_eq!(s.stall_roles, vec!["sim-serve-shard".to_owned()]);
        assert_eq!(s.conn_drop, Some(Rate::Every(3)));
        // describe() round-trips through the grammar
        assert_eq!(FaultSpec::parse(&s.describe()).unwrap(), s);
        // empty spec parses to the inert default
        assert!(FaultSpec::parse("").unwrap().is_empty());
        assert!(FaultSpec::parse("  ,  ").unwrap().is_empty());
    }

    #[test]
    fn hostile_specs_are_rejected() {
        for bad in [
            "explode",
            "conn_drop",
            "conn_drop:q=1",
            "conn_drop:p=2.0",
            "conn_drop:every=0",
            "panic:shard=x",
            "panic:ms=5",
            "delay_write:ms=abc",
            "stall:name=x",
            "seed=zzz",
            "conn_drop:p=0.1:extra=1",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    /// The `BPS_FAULT_STALL` alias accepts a comma-separated role list
    /// and merges (deduplicated) into any `stall:` clauses already
    /// parsed — multi-role pinning through either plane.
    #[test]
    fn stall_alias_accepts_multiple_roles() {
        let mut s = FaultSpec::parse("stall:role=sim-serve-shard").unwrap();
        s.add_stall_roles("scenario-feed, sim-serve-shard,wire-accept,");
        assert_eq!(
            s.stall_roles,
            vec![
                "sim-serve-shard".to_owned(),
                "scenario-feed".to_owned(),
                "wire-accept".to_owned(),
            ]
        );
        let mut empty = FaultSpec::default();
        empty.add_stall_roles("a,b");
        assert_eq!(empty.stall_roles, vec!["a".to_owned(), "b".to_owned()]);
        assert!(!empty.is_empty());
    }

    #[test]
    fn every_rates_are_exact_and_panics_one_shot() {
        let inj = Injector::new(FaultSpec::parse("conn_drop:every=3,panic:shard=1").unwrap());
        let fired: Vec<bool> = (0..9).map(|_| inj.should_drop_conn()).collect();
        assert_eq!(
            fired,
            vec![false, false, true, false, false, true, false, false, true]
        );
        assert_eq!(inj.fired_drops.load(Ordering::Relaxed), 3);
        assert!(!inj.take_panic(0), "shard 0 was never armed");
        assert!(inj.take_panic(1));
        assert!(!inj.take_panic(1), "one-shot: consumed");
        inj.arm_panic(1);
        assert!(inj.take_panic(1), "re-armed at runtime");
        assert_eq!(inj.fired_panics.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn corruption_flips_magic_and_is_seed_deterministic() {
        let inj = Injector::new(FaultSpec::parse("corrupt:every=2").unwrap());
        let mut frame = vec![0x0Cu8, 0xB5, 1, 1, 0, 0, 0, 0];
        assert!(!inj.corrupt_frame(&mut frame));
        assert_eq!(&frame[..2], &[0x0C, 0xB5], "non-firing check is a no-op");
        assert!(inj.corrupt_frame(&mut frame));
        assert_ne!(&frame[..2], &[0x0C, 0xB5], "magic flipped on fire");
        // probabilistic decisions replay identically for equal seeds
        let a = Injector::new(FaultSpec::parse("conn_drop:p=0.5,seed=42").unwrap());
        let b = Injector::new(FaultSpec::parse("conn_drop:p=0.5,seed=42").unwrap());
        let sa: Vec<bool> = (0..64).map(|_| a.should_drop_conn()).collect();
        let sb: Vec<bool> = (0..64).map(|_| b.should_drop_conn()).collect();
        assert_eq!(sa, sb);
        assert!(sa.iter().any(|&x| x) && !sa.iter().all(|&x| x));
    }
}
