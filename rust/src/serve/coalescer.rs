//! Per-shard coalescer: assembles full batch steps from partial
//! per-session submissions.
//!
//! A shard's `EnvBatch` only steps whole batches — that is where the
//! paper's amortization comes from — so multi-tenancy needs something to
//! reconcile "many clients, each owning a few env slots" with "one batch
//! step for everyone". The coalescer is that piece: it tracks which slots
//! are leased to which session, buffers each session's submitted actions,
//! and reports when a full batch can be assembled. Slots whose tenant has
//! not submitted by the straggler deadline are filled per
//! [`StragglerPolicy`]; free (unleased) slots always step with
//! `ACTION_STOP`, which ends any orphaned episode so a future tenant
//! starts on a fresh one (the "auto-reset" of re-leased slots).
//!
//! The coalescer is plain data guarded by the shard mutex in
//! `serve::server`; it does no locking or stepping itself.

use crate::obs::{Counter, Gauge};
use crate::sim::ACTION_STOP;

/// What a straggler's slots step with once the deadline passes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FillAction {
    /// Step with `ACTION_STOP`: ends the episode, fresh one next step.
    NoOp,
    /// Repeat the last action the slot stepped with.
    Repeat,
}

/// When a shard may step without waiting for every leased slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StragglerPolicy {
    /// Wait until every leased slot has an action. Deterministic: a step's
    /// action vector never depends on timing. A session that never
    /// submits stalls its co-tenants — use `Deadline` for open traffic.
    Wait,
    /// Once at least one action is pending, wait at most `ticks` driver
    /// ticks (see `serve::server::TICK`) for the rest, then fill the
    /// missing leased slots with `fill`.
    Deadline { ticks: u32, fill: FillAction },
}

/// One leased slot's coalescing state.
struct SlotLease {
    session: u64,
    pending: Option<u8>,
    /// Last action this slot stepped with (the `Repeat` fill).
    last: u8,
}

/// Lease + action-assembly state for one shard (see module docs).
pub(crate) struct Coalescer {
    policy: StragglerPolicy,
    /// `slots[i]` is `None` when slot `i` is free.
    slots: Vec<Option<SlotLease>>,
    /// Driver ticks waited since the first pending action of this step.
    waited: u32,
    /// Leased slots filled by the straggler policy, cumulative. A
    /// registry [`Counter`] so `SimServer::stats()` and a scrape read
    /// the *same* cell (bitwise-identical views; DESIGN.md §0.10).
    pub straggler_fills: Counter,
    /// Submissions rejected for a bad slot index (out of range, unleased,
    /// or leased to another session), cumulative. Nonzero only under
    /// hostile or buggy clients — slot indices arrive off the wire.
    pub bad_submits: Counter,
    /// Occupancy gauges mirrored on every mutation (lease/release/
    /// submit/assemble), so a lock-free scrape sees exactly the value a
    /// locked `stats()` scan would compute at the same instant.
    pub obs_leased: Gauge,
    pub obs_queued: Gauge,
    pub obs_occupancy: Gauge,
}

impl Coalescer {
    pub fn new(n: usize, policy: StragglerPolicy) -> Coalescer {
        Coalescer {
            policy,
            slots: (0..n).map(|_| None).collect(),
            waited: 0,
            straggler_fills: Counter::new(),
            bad_submits: Counter::new(),
            obs_leased: Gauge::new(),
            obs_queued: Gauge::new(),
            obs_occupancy: Gauge::new(),
        }
    }

    /// Re-derive the occupancy gauges from the slot table. Called at the
    /// end of every mutating method; O(slots) scans are noise next to a
    /// batch step.
    fn sync_obs(&self) {
        let leased = self.leased();
        self.obs_leased.set(leased as f64);
        self.obs_queued.set(self.pending() as f64);
        self.obs_occupancy
            .set(leased as f64 / self.slots.len().max(1) as f64);
    }

    pub fn policy(&self) -> StragglerPolicy {
        self.policy
    }

    /// Lease `want` free slots (lowest indices first) to `session`.
    /// Returns `None` — leasing nothing — when fewer than `want` are free.
    pub fn lease(&mut self, session: u64, want: usize) -> Option<Vec<usize>> {
        let free: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .take(want)
            .collect();
        if free.len() < want {
            return None;
        }
        for &i in &free {
            self.slots[i] = Some(SlotLease {
                session,
                pending: None,
                last: ACTION_STOP,
            });
        }
        self.sync_obs();
        Some(free)
    }

    /// Drop every lease and buffered action (quarantine/restart: the
    /// driver died mid-step, so the table may be mid-mutation — rebuild
    /// it empty rather than trusting partial state). Straggler-fill and
    /// bad-submit counters survive (they are cumulative diagnostics).
    pub fn clear_leases(&mut self) {
        for s in self.slots.iter_mut() {
            *s = None;
        }
        self.waited = 0;
        self.sync_obs();
    }

    /// Free every slot leased to `session` (detach).
    pub fn release(&mut self, session: u64) {
        for s in self.slots.iter_mut() {
            if s.as_ref().is_some_and(|l| l.session == session) {
                *s = None;
            }
        }
        // If the detaching session held the only pending actions, the
        // straggler-deadline clock must not keep ticking into the *next*
        // step (only `assemble` resets it otherwise): a stale `waited`
        // silently shortens the co-tenants' deadline window.
        if !self.has_pending() {
            self.waited = 0;
        }
        self.sync_obs();
    }

    /// Buffer `actions[j]` for `slots[j]`. Returns how many submissions
    /// were accepted; slots out of range or not leased to `session` are
    /// skipped and counted in `bad_submits` — slot indices arrive off the
    /// wire, so a bad index must never panic the shard driver (which
    /// calls into the coalescer while holding the shard mutex).
    pub fn submit(&mut self, session: u64, slots: &[usize], actions: &[u8]) -> usize {
        let mut accepted = 0;
        for (&i, &a) in slots.iter().zip(actions.iter()) {
            match self.slots.get_mut(i) {
                Some(Some(l)) if l.session == session => {
                    l.pending = Some(a);
                    accepted += 1;
                }
                _ => self.bad_submits.inc(),
            }
        }
        self.sync_obs();
        accepted
    }

    /// Number of leased slots (occupancy numerator).
    pub fn leased(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Number of buffered actions awaiting coalescing (queue depth).
    pub fn pending(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.as_ref().is_some_and(|l| l.pending.is_some()))
            .count()
    }

    /// True when a full batch can be assembled: at least one slot is
    /// leased and every leased slot has a pending action.
    pub fn ready(&self) -> bool {
        let mut leased = 0usize;
        for s in self.slots.iter().flatten() {
            leased += 1;
            if s.pending.is_none() {
                return false;
            }
        }
        leased > 0
    }

    /// True when at least one action is buffered (starts the deadline).
    pub fn has_pending(&self) -> bool {
        self.slots
            .iter()
            .any(|s| s.as_ref().is_some_and(|l| l.pending.is_some()))
    }

    /// One driver tick elapsed while waiting for stragglers.
    pub fn tick(&mut self) {
        self.waited = self.waited.saturating_add(1);
    }

    pub fn waited(&self) -> u32 {
        self.waited
    }

    /// Drain the buffered actions into a full batch action vector:
    /// pending actions verbatim, straggler slots per the policy's fill,
    /// free slots with `ACTION_STOP`. Resets the deadline clock.
    pub fn assemble(&mut self, out: &mut Vec<u8>) {
        out.clear();
        for s in self.slots.iter_mut() {
            let a = match s {
                Some(l) => match l.pending.take() {
                    Some(a) => {
                        l.last = a;
                        a
                    }
                    None => {
                        self.straggler_fills.inc();
                        match self.policy {
                            StragglerPolicy::Deadline {
                                fill: FillAction::Repeat,
                                ..
                            } => l.last,
                            _ => ACTION_STOP,
                        }
                    }
                },
                None => ACTION_STOP,
            };
            out.push(a);
        }
        self.waited = 0;
        self.sync_obs();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{ACTION_FORWARD, ACTION_LEFT};

    #[test]
    fn lease_release_and_re_lease_lowest_first() {
        let mut c = Coalescer::new(4, StragglerPolicy::Wait);
        let a = c.lease(1, 2).unwrap();
        assert_eq!(a, vec![0, 1]);
        let b = c.lease(2, 2).unwrap();
        assert_eq!(b, vec![2, 3]);
        assert!(c.lease(3, 1).is_none(), "full shard must refuse");
        assert_eq!(c.leased(), 4);
        c.release(1);
        assert_eq!(c.leased(), 2);
        // freed slots are re-leased lowest-first
        assert_eq!(c.lease(3, 2).unwrap(), vec![0, 1]);
    }

    #[test]
    fn ready_only_when_all_leased_have_actions() {
        let mut c = Coalescer::new(4, StragglerPolicy::Wait);
        assert!(!c.ready(), "no leases -> nothing to step");
        let a = c.lease(1, 2).unwrap();
        let b = c.lease(2, 2).unwrap();
        c.submit(1, &a, &[ACTION_FORWARD, ACTION_LEFT]);
        assert!(!c.ready() && c.has_pending());
        assert_eq!(c.pending(), 2);
        c.submit(2, &b, &[ACTION_LEFT, ACTION_LEFT]);
        assert!(c.ready());
        let mut out = Vec::new();
        c.assemble(&mut out);
        assert_eq!(out, vec![ACTION_FORWARD, ACTION_LEFT, ACTION_LEFT, ACTION_LEFT]);
        assert!(!c.has_pending(), "assemble drains the buffer");
        assert_eq!(c.straggler_fills.get(), 0);
    }

    #[test]
    fn straggler_fill_repeat_and_free_slot_filler() {
        let policy = StragglerPolicy::Deadline {
            ticks: 1,
            fill: FillAction::Repeat,
        };
        let mut c = Coalescer::new(4, policy);
        let a = c.lease(1, 1).unwrap(); // slot 0
        let b = c.lease(2, 1).unwrap(); // slot 1; slots 2,3 stay free
        c.submit(1, &a, &[ACTION_FORWARD]);
        c.submit(2, &b, &[ACTION_LEFT]);
        let mut out = Vec::new();
        c.assemble(&mut out);
        assert_eq!(out, vec![ACTION_FORWARD, ACTION_LEFT, ACTION_STOP, ACTION_STOP]);
        assert_eq!(c.straggler_fills.get(), 0, "free slots are not straggler fills");
        // next step: session 2 straggles -> its slot repeats ACTION_LEFT
        c.submit(1, &a, &[ACTION_FORWARD]);
        c.assemble(&mut out);
        assert_eq!(out, vec![ACTION_FORWARD, ACTION_LEFT, ACTION_STOP, ACTION_STOP]);
        assert_eq!(c.straggler_fills.get(), 1);
    }

    #[test]
    fn straggler_fill_noop_stops() {
        let policy = StragglerPolicy::Deadline {
            ticks: 1,
            fill: FillAction::NoOp,
        };
        let mut c = Coalescer::new(2, policy);
        let a = c.lease(1, 1).unwrap();
        let _b = c.lease(2, 1).unwrap();
        c.submit(1, &a, &[ACTION_FORWARD]);
        let mut out = Vec::new();
        c.assemble(&mut out);
        assert_eq!(out, vec![ACTION_FORWARD, ACTION_STOP]);
    }

    /// Regression: a slot index >= batch size (or aimed at a free or
    /// foreign slot) must be skipped and counted, never panic — these
    /// indices arrive off the wire and the caller holds the shard mutex.
    #[test]
    fn bad_slot_indices_are_skipped_and_counted() {
        let mut c = Coalescer::new(4, StragglerPolicy::Wait);
        let a = c.lease(1, 2).unwrap(); // slots 0,1
        // out-of-range index: skipped, counted, no panic
        assert_eq!(c.submit(1, &[usize::MAX], &[ACTION_FORWARD]), 0);
        assert_eq!(c.bad_submits.get(), 1);
        // free slot (2) and a foreign lease's slot are equally rejected
        let _b = c.lease(2, 1).unwrap(); // slot 2
        assert_eq!(
            c.submit(1, &[a[0], 2, 9999], &[ACTION_FORWARD; 3]),
            1,
            "only the owned in-range slot is accepted"
        );
        assert_eq!(c.bad_submits.get(), 3);
        assert_eq!(c.pending(), 1, "rejected submissions buffer nothing");
        // the accepted action still assembles normally
        c.submit(1, &a[1..], &[ACTION_LEFT]);
        c.submit(2, &[2], &[ACTION_LEFT]);
        let mut out = Vec::new();
        c.assemble(&mut out);
        assert_eq!(out, vec![ACTION_FORWARD, ACTION_LEFT, ACTION_LEFT, ACTION_STOP]);
    }

    /// Regression: when the only session with pending actions detaches,
    /// the straggler-deadline clock must reset — a stale `waited` would
    /// silently shorten the next step's deadline window for co-tenants.
    #[test]
    fn deadline_clock_resets_when_detach_drains_pending() {
        let policy = StragglerPolicy::Deadline {
            ticks: 5,
            fill: FillAction::NoOp,
        };
        let mut c = Coalescer::new(4, policy);
        let a = c.lease(1, 2).unwrap();
        let _b = c.lease(2, 2).unwrap();
        c.submit(1, &a, &[ACTION_FORWARD, ACTION_FORWARD]);
        c.tick();
        c.tick();
        assert_eq!(c.waited(), 2);
        // session 1 detaches with its actions still buffered: the clock
        // must reset, or session 2's next step gets a 3-tick window
        c.release(1);
        assert!(!c.has_pending());
        assert_eq!(c.waited(), 0, "stale deadline clock after detach");
        // a detach that does NOT drain the last pending action keeps the
        // clock: the in-flight step's window is still being measured
        let a2 = c.lease(3, 2).unwrap();
        c.submit(3, &a2, &[ACTION_FORWARD, ACTION_FORWARD]);
        c.tick();
        c.release(2);
        assert!(c.has_pending());
        assert_eq!(c.waited(), 1, "clock keeps running for live pendings");
    }

    /// Quarantine path: `clear_leases` empties the whole table (leases
    /// *and* buffered actions), resets the deadline clock, and leaves
    /// the gauges consistent, so a restarted shard starts coherent.
    #[test]
    fn clear_leases_resets_the_table_wholesale() {
        let mut c = Coalescer::new(4, StragglerPolicy::Wait);
        let a = c.lease(1, 2).unwrap();
        let _b = c.lease(2, 1).unwrap();
        c.submit(1, &a, &[ACTION_FORWARD, ACTION_LEFT]);
        c.tick();
        assert_eq!(c.leased(), 3);
        assert!(c.has_pending());
        c.clear_leases();
        assert_eq!(c.leased(), 0);
        assert!(!c.has_pending() && !c.ready());
        assert_eq!(c.waited(), 0);
        assert_eq!(c.obs_leased.get(), 0.0);
        assert_eq!(c.obs_queued.get(), 0.0);
        // the table is immediately re-leasable, lowest-first
        assert_eq!(c.lease(3, 4).unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn deadline_clock_resets_on_assemble() {
        let mut c = Coalescer::new(1, StragglerPolicy::Wait);
        let a = c.lease(1, 1).unwrap();
        c.tick();
        c.tick();
        assert_eq!(c.waited(), 2);
        c.submit(1, &a, &[ACTION_FORWARD]);
        let mut out = Vec::new();
        c.assemble(&mut out);
        assert_eq!(c.waited(), 0);
    }
}
