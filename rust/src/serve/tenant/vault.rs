//! [`PolicyVault`]: the server's checkpoint store — resolves a variant
//! name to (manifest entry, flat parameter vector) for tenant leases.
//!
//! The vault reuses exactly the artifact plumbing `coordinator::eval`
//! uses: `artifacts/manifest.json` names the variants and their AOT
//! `infer_n{N}` executables, and parameters come from either a
//! `ParamStore` checkpoint (`bps train` output) or, absent one, the
//! deterministic `init` artifact seeded with the vault seed — which is
//! what makes the tenant-vs-local equivalence tests possible: both sides
//! init from the same seed and must produce the same bits.
//!
//! Everything here is metadata plus a params cache; no XLA executable is
//! loaded on vault threads. Executables are `Rc`-held and not `Send`, so
//! all `Exec` work (including running `init`) happens on the per-shard
//! tenant driver thread, which passes its own `Runtime` into
//! [`params_for`](PolicyVault::params_for).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::runtime::{Manifest, ParamStore, Runtime, Variant};

/// Server-side policy checkpoint store (see module docs).
pub struct PolicyVault {
    man: Manifest,
    checkpoint: Option<PathBuf>,
    seed: u64,
    /// variant name → resolved flat params. Filled lazily by driver
    /// threads; init is deterministic, so a racing double-resolve costs
    /// compute but never disagrees.
    params: Mutex<HashMap<String, Arc<Vec<f32>>>>,
}

impl PolicyVault {
    /// Open a vault over `artifacts_dir` (must hold `manifest.json`).
    /// With a checkpoint, leases serve its trained parameters; without
    /// one, each variant's `init` artifact is run with `seed`.
    pub fn open(artifacts_dir: &Path, checkpoint: Option<PathBuf>, seed: u64) -> Result<PolicyVault> {
        let man = Manifest::load(artifacts_dir)
            .with_context(|| format!("policy vault: open {}", artifacts_dir.display()))?;
        if let Some(ckpt) = &checkpoint {
            if !ckpt.exists() {
                bail!("policy vault: checkpoint {} not found", ckpt.display());
            }
        }
        Ok(PolicyVault {
            man,
            checkpoint,
            seed,
            params: Mutex::new(HashMap::new()),
        })
    }

    /// [`open`](PolicyVault::open), but absent artifacts is not an error:
    /// returns `Ok(None)` when `manifest.json` is missing, which is how
    /// every tenant path stays gated exactly like the coordinator's eval
    /// (CI without artifacts serves envs but declines policy leases).
    pub fn open_if_present(
        artifacts_dir: &Path,
        checkpoint: Option<PathBuf>,
        seed: u64,
    ) -> Result<Option<PolicyVault>> {
        if !artifacts_dir.join("manifest.json").exists() {
            return Ok(None);
        }
        PolicyVault::open(artifacts_dir, checkpoint, seed).map(Some)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.man
    }

    /// Resolve a variant by name (cloned so callers don't borrow the
    /// vault across lease bookkeeping).
    pub fn variant(&self, name: &str) -> Result<Variant> {
        self.man.variant(name).cloned()
    }

    /// One-line description for the serve banner.
    pub fn describe(&self) -> String {
        let variants: Vec<&str> = self.man.variants.keys().map(String::as_str).collect();
        match &self.checkpoint {
            Some(p) => format!("variants {variants:?}, checkpoint {}", p.display()),
            None => format!("variants {variants:?}, init seed {}", self.seed),
        }
    }

    /// Flat parameters for `variant`, resolved once and cached. Called
    /// from tenant driver threads with the driver's own `Runtime`.
    pub(crate) fn params_for(&self, rt: &Runtime, variant: &Variant) -> Result<Arc<Vec<f32>>> {
        if let Some(p) = self.params.lock().unwrap().get(&variant.name) {
            return Ok(Arc::clone(p));
        }
        let flat = match &self.checkpoint {
            Some(ckpt) => {
                let store = ParamStore::load(ckpt)
                    .with_context(|| format!("policy vault: load {}", ckpt.display()))?;
                if store.flat.len() != variant.num_params {
                    bail!(
                        "policy vault: checkpoint {} holds {} params but variant {:?} \
                         needs {} — it was trained for a different variant",
                        ckpt.display(),
                        store.flat.len(),
                        variant.name,
                        variant.num_params
                    );
                }
                store.flat
            }
            None => {
                let init = rt.load(&self.man.artifact_path(variant, "init")?)?;
                ParamStore::init(&init, variant.num_params, self.seed as i32)?.flat
            }
        };
        let flat = Arc::new(flat);
        self.params
            .lock()
            .unwrap()
            .entry(variant.name.clone())
            .or_insert_with(|| Arc::clone(&flat));
        Ok(flat)
    }
}
