//! [`TenantSession`]: a client's lease of env slots *plus* a server-side
//! policy — the client sets goals and streams trajectories back; the
//! server closes the act→observe loop itself.
//!
//! Where a plain [`Session`](crate::serve::Session) hands the client an
//! observation and waits for actions, a tenant session inverts control:
//! [`set_goal`](TenantSession::set_goal) asks the shard's tenant driver
//! to drive the lease for N steps, and [`next_step`](
//! TenantSession::next_step) receives one [`TrajStep`] per server-driven
//! step (actions chosen, rewards earned, next observation). The handle
//! never touches the policy or the shard directly; everything flows
//! through the per-shard `TenantShared` registry (`tenant::driver`) and
//! a bounded trajectory channel.
//!
//! [`TenantControl`] is the handle's cheap, cloneable control plane
//! (goal posting + detach). The wire layer keeps a clone per remote
//! tenant so the connection reader can route `GOAL` frames without
//! owning the trajectory stream.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::sim::Task;

use super::driver::{lock_tenants, TenantShared};

/// How the server picks actions for a tenant lease.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActionMode {
    /// Argmax actions — deterministic, and bitwise-comparable to a
    /// client-side `Policy::step_greedy` loop (the equivalence tests).
    Greedy,
    /// Categorical sampling from the policy, on a per-tenant RNG stream
    /// seeded here — co-tenants never perturb each other's draws.
    Sample { seed: u64 },
}

/// One server-driven step of a tenant lease: the actions the policy
/// chose for the leased slots plus the resulting step slice (same SoA
/// shape as [`SessionView`](crate::serve::SessionView), owned).
#[derive(Clone, Debug, Default)]
pub struct TrajStep {
    /// Shard batch step these results belong to.
    pub step: u64,
    /// Action stepped per leased slot (empty in the initial snapshot).
    pub actions: Vec<u8>,
    pub obs: Vec<f32>,
    pub goal: Vec<f32>,
    pub rewards: Vec<f32>,
    pub dones: Vec<bool>,
    pub successes: Vec<bool>,
    pub spl: Vec<f32>,
    pub scores: Vec<f32>,
}

/// Driver → handle trajectory stream payload.
pub(crate) enum TrajMsg {
    Step(TrajStep),
    Error(String),
}

pub(crate) struct ControlInner {
    shared: Arc<TenantShared>,
    tenant: u64,
    detached: AtomicBool,
}

impl ControlInner {
    fn detach(&self) {
        if self.detached.swap(true, Ordering::SeqCst) {
            return;
        }
        let mut st = lock_tenants(&self.shared.state);
        st.coal.unregister(self.tenant);
        st.detached.push(self.tenant);
        // Wake the driver: it may now have a complete tick (every
        // remaining tenant active), or a member to reap.
        self.shared.posted.notify_all();
    }
}

impl Drop for ControlInner {
    fn drop(&mut self) {
        self.detach();
    }
}

/// Cloneable control plane of a [`TenantSession`] (goal posting and
/// detach, no trajectory stream). Dropping the last clone detaches.
#[derive(Clone)]
pub struct TenantControl {
    inner: Arc<ControlInner>,
}

impl TenantControl {
    pub(crate) fn new(shared: Arc<TenantShared>, tenant: u64) -> TenantControl {
        TenantControl {
            inner: Arc::new(ControlInner {
                shared,
                tenant,
                detached: AtomicBool::new(false),
            }),
        }
    }

    /// Ask the server to drive this lease for `steps` more steps. Goals
    /// accumulate; each goal posted from idle starts with fresh recurrent
    /// state. One [`TrajStep`] arrives per step on the session stream.
    pub fn set_goal(&self, steps: u32) -> Result<()> {
        if steps == 0 {
            bail!("set_goal: a goal needs at least one step");
        }
        if self.inner.detached.load(Ordering::SeqCst) {
            bail!("set_goal on a detached tenant session");
        }
        let mut st = lock_tenants(&self.inner.shared.state);
        if st.shutdown {
            let msg = st.error.clone().unwrap_or_else(|| "server shut down".into());
            bail!("serve: {msg}");
        }
        if !st.coal.set_goal(self.inner.tenant, steps) {
            bail!("set_goal on a detached tenant session");
        }
        self.inner.shared.posted.notify_all();
        Ok(())
    }

    /// Free the lease: the driver drops the member's slots back to the
    /// shard (auto-reset filler) and ends the trajectory stream.
    /// Idempotent; also runs when the last control clone drops.
    pub fn detach(&self) {
        self.inner.detach();
    }

    pub fn detached(&self) -> bool {
        self.inner.detached.load(Ordering::SeqCst)
    }
}

/// A policy-tenant lease (see module docs). `Send`: connect on one
/// thread, stream from another.
pub struct TenantSession {
    control: TenantControl,
    task: Task,
    obs_floats: usize,
    slots: Vec<usize>,
    rx: Receiver<TrajMsg>,
    /// The lease's initial observation snapshot (`actions` empty),
    /// gathered before the driver stepped anything — what a plain
    /// session's first `view()` would show.
    initial: TrajStep,
    steps: u64,
}

impl TenantSession {
    pub(crate) fn new(
        control: TenantControl,
        task: Task,
        obs_floats: usize,
        slots: Vec<usize>,
        rx: Receiver<TrajMsg>,
        initial: TrajStep,
    ) -> TenantSession {
        TenantSession {
            control,
            task,
            obs_floats,
            slots,
            rx,
            initial,
            steps: 0,
        }
    }

    pub fn num_envs(&self) -> usize {
        self.slots.len()
    }

    pub fn task(&self) -> Task {
        self.task
    }

    /// Floats per env observation tile (shard render config).
    pub fn obs_floats(&self) -> usize {
        self.obs_floats
    }

    /// The shard slot indices backing this lease (ascending).
    pub fn slots(&self) -> &[usize] {
        &self.slots
    }

    /// The initial observation snapshot (before any server-driven step).
    pub fn initial(&self) -> &TrajStep {
        &self.initial
    }

    /// Server-driven steps streamed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// A cloneable control-plane handle (see [`TenantControl`]).
    pub fn control(&self) -> TenantControl {
        self.control.clone()
    }

    /// See [`TenantControl::set_goal`].
    pub fn set_goal(&self, steps: u32) -> Result<()> {
        self.control.set_goal(steps)
    }

    /// Block for the next server-driven step. `Ok(None)` means the
    /// session detached cleanly (no more steps will arrive); `Err` means
    /// the shard or the policy failed mid-goal.
    pub fn next_step(&mut self) -> Result<Option<TrajStep>> {
        match self.rx.recv() {
            Ok(TrajMsg::Step(ts)) => {
                self.steps += 1;
                Ok(Some(ts))
            }
            Ok(TrajMsg::Error(msg)) => bail!("serve: {msg}"),
            Err(_) => {
                // Driver hung up: detached, server shut down, or the
                // driver dropped us after this handle stalled.
                if self.control.detached() {
                    return Ok(None);
                }
                let st = lock_tenants(&self.control.inner.shared.state);
                if let Some(msg) = &st.error {
                    bail!("serve: {msg}");
                }
                if st.shutdown {
                    bail!("serve: server shut down");
                }
                Ok(None)
            }
        }
    }

    /// See [`TenantControl::detach`]. Idempotent; also runs on drop.
    pub fn detach(&self) {
        self.control.detach();
    }
}
