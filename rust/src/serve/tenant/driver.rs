//! The per-shard **tenant driver**: one thread that closes the
//! act→observe loop for every policy tenant of a shard.
//!
//! Each tick is `observe → coalesced infer → pick actions → submit`:
//!
//! 1. Snapshot the shard's latest published [`StepResult`] — the full
//!    batch observation, already resident (no gather: the policy runs at
//!    shard width, so tenant slices are just rows of the batch).
//! 2. One `Exec::run` per (shard, variant) group regardless of tenant
//!    count — the [`InferenceCoalescer`] decides when the tick fires
//!    (`Wait`: every tenant has an active goal; `Deadline`: at least one
//!    does and the clock ran out), exactly like the action coalescer one
//!    layer down.
//! 3. Per tenant, slice its slots' logit rows: argmax for `Greedy`
//!    tenants, categorical sampling on the tenant's own RNG stream for
//!    `Sample` tenants. Idle tenants' slots are filled per the shard's
//!    [`FillAction`] (STOP or repeat-last).
//! 4. Submit every member's actions through its ordinary [`Session`] —
//!    all submissions before any wait, or a `Wait`-policy shard would
//!    deadlock against itself — then wait the tickets and stream each
//!    active member one [`TrajStep`].
//!
//! `Exec` is `Rc`-held (not `Send`), so the driver builds its own
//! `Runtime` and loads `infer_n{width}` itself; the [`PolicyVault`] only
//! hands it paths and (`Send`) parameter vectors. Recurrent state lives
//! here too, full-width per variant, with rows zeroed at goal start, on
//! episode end, and for slots no tenant of that variant owns.

use std::collections::HashMap;
use std::sync::mpsc::{SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::metrics::Window;
use crate::obs::{Counter, Heartbeat};
use crate::policy::{argmax_action, Policy};
use crate::runtime::Runtime;
use crate::serve::coalescer::{FillAction, StragglerPolicy};
use crate::serve::server::{lock_state, ShardShared, TICK};
use crate::serve::session::Session;
use crate::sim::ACTION_STOP;

use super::coalescer::{InferenceCoalescer, TickShare};
use super::session::{ActionMode, TrajMsg, TrajStep};
use super::vault::PolicyVault;

/// Trajectory steps buffered per tenant handle before the driver blocks
/// on the consumer. A remote tenant's backpressure is the wire outbox
/// (slow readers get disconnected there); an in-process tenant that
/// stops reading stalls its co-tenants, same as a `Wait`-policy session
/// that stops submitting.
pub(crate) const TRAJ_QUEUE: usize = 8;

/// How many per-stage latency samples the tenant window keeps.
const TENANT_LATENCY_WINDOW: usize = 4096;

/// A pending lease hand-off from `connect_with_policy` to the driver.
pub(crate) struct Join {
    pub tenant: u64,
    pub session: Session,
    pub mode: ActionMode,
    pub variant: String,
    pub tx: SyncSender<TrajMsg>,
}

/// Mutex-guarded tenant registry + counters for one shard.
pub(crate) struct TenantState {
    pub coal: InferenceCoalescer,
    /// Leases accepted but not yet adopted by the driver.
    pub joins: Vec<Join>,
    /// Tenants detached since the driver last looked.
    pub detached: Vec<u64>,
    pub shutdown: bool,
    pub error: Option<String>,
    /// `Exec::run` invocations, cumulative. Registry [`Counter`]s so
    /// `SimServer::stats()` and scrapes read the same cells.
    pub infer_runs: Counter,
    /// Server-driven env steps (sum of active members' slot counts).
    pub agent_steps: Counter,
    // Per-stage tick latency samples (seconds).
    pub gather_lat: Window,
    pub infer_lat: Window,
    pub step_lat: Window,
}

/// One shard's tenant registry as seen by handles and the driver thread.
pub(crate) struct TenantShared {
    /// Inference batch width == the shard's slot count.
    pub width: usize,
    pub state: Mutex<TenantState>,
    /// Handles → driver: goal posted / member joined / detached /
    /// shutdown.
    pub posted: Condvar,
}

/// Poison-recovering lock on a tenant registry. A tenant driver that
/// panicked mid-lock poisons the mutex; handles and the panic
/// supervisor still need the state (to read the error, to mark the
/// shutdown), so everyone recovers the guard instead of propagating.
pub(crate) fn lock_tenants(m: &Mutex<TenantState>) -> MutexGuard<'_, TenantState> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Supervisor-side cleanup after a caught tenant-driver panic: fail the
/// registry so every handle (and future `connect_with_policy`) sees the
/// error instead of hanging on a condvar nobody will signal. The
/// members' trajectory senders died with the driver thread, so handles
/// blocked in `recv` wake via disconnect and read this error.
pub(crate) fn quarantine_tenants(shared: &TenantShared, msg: String) {
    let mut st = lock_tenants(&shared.state);
    st.shutdown = true;
    if st.error.is_none() {
        st.error = Some(msg);
    }
    shared.posted.notify_all();
}

impl TenantShared {
    pub fn new(width: usize, policy: StragglerPolicy) -> TenantShared {
        TenantShared {
            width,
            state: Mutex::new(TenantState {
                coal: InferenceCoalescer::new(policy),
                joins: Vec::new(),
                detached: Vec::new(),
                shutdown: false,
                error: None,
                infer_runs: Counter::new(),
                agent_steps: Counter::new(),
                gather_lat: Window::new(TENANT_LATENCY_WINDOW),
                infer_lat: Window::new(TENANT_LATENCY_WINDOW),
                step_lat: Window::new(TENANT_LATENCY_WINDOW),
            }),
            posted: Condvar::new(),
        }
    }
}

/// One adopted tenant, owned by the driver thread.
struct MemberState {
    tenant: u64,
    session: Session,
    slots: Vec<usize>,
    variant: String,
    greedy: bool,
    rng: crate::util::rng::Rng,
    tx: SyncSender<TrajMsg>,
    /// Actions staged for the current tick; between ticks, the last
    /// actions stepped (the `Repeat` idle fill).
    staged: Vec<u8>,
}

/// One policy variant's executable + full-width recurrent state.
struct Engine {
    policy: Policy,
    params: Arc<Vec<f32>>,
}

enum Wake {
    Tick(Vec<TickShare>),
    Membership { joins: Vec<Join>, detached: Vec<u64> },
    Shutdown,
}

/// Driver entry point (one thread per shard with policy tenants).
pub(crate) fn tenant_driver(
    shared: Arc<TenantShared>,
    shard: Arc<ShardShared>,
    vault: Arc<PolicyVault>,
    hb: Heartbeat,
) {
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            fail(&shared, &mut HashMap::new(), format!("tenant runtime: {e:#}"));
            return;
        }
    };
    let width = shared.width;
    let mut members: HashMap<u64, MemberState> = HashMap::new();
    let mut engines: HashMap<String, Engine> = HashMap::new();
    loop {
        // Phase 1: wait until a tick can fire (or membership changed).
        let wake = {
            let mut st = lock_tenants(&shared.state);
            loop {
                if st.shutdown {
                    break Wake::Shutdown;
                }
                if !st.joins.is_empty() || !st.detached.is_empty() {
                    break Wake::Membership {
                        joins: std::mem::take(&mut st.joins),
                        detached: std::mem::take(&mut st.detached),
                    };
                }
                if st.coal.ready() {
                    break Wake::Tick(st.coal.begin_tick());
                }
                match st.coal.policy() {
                    StragglerPolicy::Deadline { ticks, .. } if st.coal.has_active() => {
                        if st.coal.waited() >= ticks {
                            break Wake::Tick(st.coal.begin_tick());
                        }
                        let (guard, timeout) = shared
                            .posted
                            .wait_timeout(st, TICK)
                            .unwrap_or_else(|e| e.into_inner());
                        st = guard;
                        if timeout.timed_out() {
                            st.coal.tick();
                        }
                    }
                    _ => {
                        // Deliberate unbounded park, not a stall.
                        hb.idle();
                        st = shared.posted.wait(st).unwrap_or_else(|e| e.into_inner());
                    }
                }
            }
        };
        // Beat after every wake so a tick wedged below goes silent.
        hb.beat();
        match wake {
            Wake::Shutdown => {
                let msg = {
                    let st = lock_tenants(&shared.state);
                    st.error.clone().unwrap_or_else(|| "server shut down".into())
                };
                for m in members.values() {
                    let _ = m.tx.try_send(TrajMsg::Error(msg.clone()));
                }
                return;
            }
            Wake::Membership { joins, detached } => {
                for id in detached {
                    members.remove(&id); // Session drop releases the lease
                }
                for j in joins {
                    adopt(&rt, &vault, &shared, &mut engines, &mut members, j, width);
                }
            }
            Wake::Tick(plan) => {
                if !run_tick(&shared, &shard, &mut engines, &mut members, &plan, width) {
                    return;
                }
            }
        }
    }
}

/// Load (or reuse) the variant engine and adopt a joined member. On
/// engine failure the member alone is failed; co-tenants keep running.
fn adopt(
    rt: &Runtime,
    vault: &PolicyVault,
    shared: &TenantShared,
    engines: &mut HashMap<String, Engine>,
    members: &mut HashMap<u64, MemberState>,
    j: Join,
    width: usize,
) {
    if !engines.contains_key(&j.variant) {
        let built = (|| -> anyhow::Result<Engine> {
            let variant = vault.variant(&j.variant)?;
            let params = vault.params_for(rt, &variant)?;
            let policy = Policy::new(rt, vault.manifest(), &variant, width, 0)?;
            Ok(Engine { policy, params })
        })();
        match built {
            Ok(engine) => {
                engines.insert(j.variant.clone(), engine);
            }
            Err(e) => {
                let _ = j
                    .tx
                    .try_send(TrajMsg::Error(format!("policy engine: {e:#}")));
                lock_tenants(&shared.state).coal.unregister(j.tenant);
                return; // j.session drops here: lease released
            }
        }
    }
    let slots = j.session.slots().to_vec();
    let n = slots.len();
    members.insert(
        j.tenant,
        MemberState {
            tenant: j.tenant,
            session: j.session,
            slots,
            variant: j.variant,
            greedy: matches!(j.mode, ActionMode::Greedy),
            rng: crate::util::rng::Rng::new(match j.mode {
                ActionMode::Sample { seed } => seed,
                ActionMode::Greedy => 0,
            }),
            tx: j.tx,
            staged: vec![ACTION_STOP; n],
        },
    );
}

/// One coalesced tick. Returns `false` when the shard died and the
/// driver must exit.
fn run_tick(
    shared: &TenantShared,
    shard: &ShardShared,
    engines: &mut HashMap<String, Engine>,
    members: &mut HashMap<u64, MemberState>,
    plan: &[TickShare],
    width: usize,
) -> bool {
    let fill = match lock_tenants(&shared.state).coal.policy() {
        StragglerPolicy::Deadline { fill, .. } => fill,
        StragglerPolicy::Wait => FillAction::NoOp,
    };
    // Observe: the shard's latest published step IS the batch input —
    // tenants are rows of it, no gather needed.
    let t0 = Instant::now();
    let snapshot = Arc::clone(&lock_state(&shard.state).result);
    // Fresh goals start from zeroed recurrent rows, like a fresh
    // client-side Policy.
    let mut reset = vec![false; width];
    for share in plan.iter().filter(|s| s.fresh) {
        if let Some(m) = members.get(&share.tenant) {
            for &slot in &m.slots {
                reset[slot] = true;
            }
        }
    }
    let gather_d = t0.elapsed();
    let gather_s = gather_d.as_secs_f32();
    // Coalesced infer: one Exec::run per variant with >=1 active member.
    let t1 = Instant::now();
    let mut logits: HashMap<String, Vec<f32>> = HashMap::new();
    let mut runs = 0u64;
    for share in plan.iter().filter(|s| s.active) {
        let Some(m) = members.get(&share.tenant) else { continue };
        if logits.contains_key(&m.variant) {
            continue;
        }
        let variant = m.variant.clone();
        let eng = engines.get_mut(&variant).unwrap();
        eng.policy.reset_done(&reset);
        match eng.policy.logits_step(&eng.params, &snapshot.obs, &snapshot.goal) {
            Ok(l) => {
                logits.insert(variant, l);
                runs += 1;
            }
            Err(e) => {
                fail(shared, members, format!("tenant inference: {e:#}"));
                return false;
            }
        }
    }
    let infer_d = t1.elapsed();
    let infer_s = infer_d.as_secs_f32();
    // Latency attribution: inference happens *before* submit, so the
    // ticket's end-to-end wait never contains it — observe it directly
    // into the phase histogram here instead of via `Ticket::wait`.
    shard.phase.infer.observe(infer_d.as_micros() as u64);
    // Pick actions: per-tenant rows of the batched logits; idle members
    // get the straggler fill.
    let mut agent_steps = 0u64;
    for share in plan {
        let Some(m) = members.get_mut(&share.tenant) else { continue };
        if share.active {
            let l = &logits[m.variant.as_str()];
            let a = engines[&m.variant].policy.num_actions;
            for (j, &slot) in m.slots.iter().enumerate() {
                let row = &l[slot * a..(slot + 1) * a];
                m.staged[j] = if m.greedy {
                    argmax_action(row)
                } else {
                    m.rng.categorical(row).0 as u8
                };
            }
            agent_steps += m.slots.len() as u64;
        } else if fill == FillAction::NoOp {
            m.staged.fill(ACTION_STOP);
        } // Repeat: staged still holds the last stepped actions
    }
    // Submit every member, then wait — all submissions must land before
    // any wait or a Wait-policy shard coalescer would never fire.
    let t2 = Instant::now();
    let active: HashMap<u64, bool> = plan.iter().map(|s| (s.tenant, s.active)).collect();
    let mut stalled: Vec<u64> = Vec::new();
    let mut resets: Vec<(String, Vec<usize>)> = Vec::new();
    let mut tick_err: Option<String> = None;
    {
        let mut inflight = Vec::with_capacity(members.len());
        for m in members.values_mut() {
            let MemberState {
                tenant,
                session,
                slots,
                variant,
                tx,
                staged,
                ..
            } = m;
            match session.submit(staged) {
                Ok(ticket) => inflight.push((*tenant, slots, variant, tx, staged, ticket)),
                Err(e) => {
                    tick_err = Some(format!("tenant submit: {e:#}"));
                    break;
                }
            }
        }
        if tick_err.is_none() {
            for (tenant, slots, variant, tx, staged, ticket) in inflight {
                let view = match ticket.wait() {
                    Ok(v) => v,
                    Err(e) => {
                        tick_err = Some(format!("tenant step: {e:#}"));
                        break;
                    }
                };
                let done_slots: Vec<usize> = slots
                    .iter()
                    .zip(view.dones)
                    .filter(|(_, &d)| d)
                    .map(|(&s, _)| s)
                    .collect();
                if !done_slots.is_empty() {
                    resets.push((variant.clone(), done_slots));
                }
                if active.get(&tenant).copied().unwrap_or(false) {
                    let ts = TrajStep {
                        step: view.step,
                        actions: staged.clone(),
                        obs: view.obs.to_vec(),
                        goal: view.goal.to_vec(),
                        rewards: view.rewards.to_vec(),
                        dones: view.dones.to_vec(),
                        successes: view.successes.to_vec(),
                        spl: view.spl.to_vec(),
                        scores: view.scores.to_vec(),
                    };
                    // Blocking-send semantics (a stalled in-process
                    // consumer stalls its co-tenants, like a Wait-policy
                    // session that stops submitting) — but poll the
                    // shutdown flag so server drop can't deadlock on a
                    // full trajectory queue.
                    let mut msg = TrajMsg::Step(ts);
                    loop {
                        match tx.try_send(msg) {
                            Ok(()) => break,
                            Err(TrySendError::Disconnected(_)) => {
                                stalled.push(tenant);
                                break;
                            }
                            Err(TrySendError::Full(m)) => {
                                if lock_tenants(&shared.state).shutdown {
                                    stalled.push(tenant);
                                    break;
                                }
                                std::thread::sleep(TICK);
                                msg = m;
                            }
                        }
                    }
                }
            }
        }
    }
    if let Some(msg) = tick_err {
        fail(shared, members, msg);
        return false;
    }
    let step_d = t2.elapsed();
    let step_s = step_d.as_secs_f32();
    if shard.trace.enabled() {
        // The three tenant phases, on the shard's process row so a
        // Perfetto load lines them up against that tick's sim/render
        // spans. Start = now - dur (phases ran back-to-back just above).
        let pid = shard.idx as u32;
        let now = shard.trace.now_us();
        let step_us = step_d.as_micros() as u64;
        let infer_us = infer_d.as_micros() as u64;
        let gather_us = gather_d.as_micros() as u64;
        let tick = snapshot.step + 1;
        let t = &shard.trace;
        t.span(pid, "tenant", "tenant.gather",
            now.saturating_sub(step_us + infer_us + gather_us), gather_d, tick);
        t.span(pid, "tenant", "tenant.infer",
            now.saturating_sub(step_us + infer_us), infer_d, tick);
        t.span(pid, "tenant", "tenant.step", now.saturating_sub(step_us), step_d, tick);
    }
    // Episode ends zero recurrent rows (matches Policy::reset_done on
    // the client-side loop); so do rows no member of the variant owns,
    // which keeps co-resident plain sessions' slots from accumulating
    // recurrent garbage between leases.
    for (variant, eng) in engines.iter_mut() {
        let mut mask = vec![true; width];
        for m in members.values().filter(|m| &m.variant == variant) {
            for &slot in &m.slots {
                mask[slot] = false;
            }
        }
        for (v, slots) in &resets {
            if v == variant {
                for &slot in slots {
                    mask[slot] = true;
                }
            }
        }
        eng.policy.reset_done(&mask);
    }
    // Publish counters; reap members whose handle hung up mid-stream.
    {
        let mut st = lock_tenants(&shared.state);
        st.infer_runs.add(runs);
        st.agent_steps.add(agent_steps);
        st.gather_lat.push(gather_s);
        st.infer_lat.push(infer_s);
        st.step_lat.push(step_s);
        for tenant in &stalled {
            st.coal.unregister(*tenant);
        }
    }
    for tenant in stalled {
        members.remove(&tenant);
    }
    true
}

/// Terminal failure: tell every member, poison the registry, exit.
fn fail(shared: &TenantShared, members: &mut HashMap<u64, MemberState>, msg: String) {
    for m in members.values() {
        let _ = m.tx.try_send(TrajMsg::Error(msg.clone()));
    }
    members.clear();
    let mut st = lock_tenants(&shared.state);
    st.shutdown = true;
    st.error = Some(msg);
    shared.posted.notify_all();
}
