//! Per-shard **inference** coalescer: decides when the tenant driver runs
//! one batched policy forward for every tenant session sharing the shard.
//!
//! This mirrors [`serve::coalescer`](crate::serve::coalescer) one level
//! up the stack. The action coalescer reconciles "many clients, each
//! owning a few env slots" with "one batch step for everyone"; this one
//! reconciles "many tenants, each with its own goal" with "one `Exec::run`
//! per tick for everyone". The analogy is exact:
//!
//! | action coalescer            | inference coalescer                |
//! |-----------------------------|------------------------------------|
//! | leased slot                 | registered tenant                  |
//! | pending action              | active goal (steps remaining > 0)  |
//! | `assemble` → action vector  | `begin_tick` → per-tenant shares   |
//! | straggler fill              | idle-tenant fill (`STOP`/repeat)   |
//!
//! The same [`StragglerPolicy`] drives readiness: `Wait` runs a tick only
//! when *every* registered tenant has an active goal (deterministic —
//! tick membership never depends on timing); `Deadline` runs once at
//! least one tenant is active and the deadline passes, filling idle
//! tenants' slots per the policy's [`FillAction`].
//!
//! Like its sibling, this is plain data guarded by the tenant mutex in
//! `serve::tenant::driver`; it does no locking, inference, or stepping
//! itself, which is what keeps it unit-testable without AOT artifacts.

use super::super::coalescer::StragglerPolicy;
use crate::obs::{Counter, Gauge};

/// Cap on a tenant's buffered goal steps — goals accumulate
/// (`set_goal` while active extends the horizon), and an unbounded
/// horizon from a hostile client would pin the driver forever.
pub const MAX_GOAL_STEPS: u32 = 1 << 20;

/// One registered tenant's coalescing state.
struct Member {
    tenant: u64,
    /// Goal steps still to drive. Zero = idle.
    remaining: u32,
    /// The next tick is this tenant's first after idling: the driver
    /// must zero its recurrent-state rows so every goal starts from the
    /// same `h = c = 0` a fresh client-side `Policy` would.
    fresh: bool,
}

/// One tenant's share of a tick (returned by
/// [`InferenceCoalescer::begin_tick`], registration order).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TickShare {
    pub tenant: u64,
    /// Participates in this tick (goal active). Idle members' slots are
    /// filled by the driver instead (STOP or repeat, per the policy).
    pub active: bool,
    /// First tick of a goal posted while idle — reset recurrent rows.
    pub fresh: bool,
}

/// Goal + tick-assembly state for one shard's tenants (see module docs).
pub struct InferenceCoalescer {
    policy: StragglerPolicy,
    /// Registration order; order is stable so tick plans are too.
    members: Vec<Member>,
    /// Driver ticks waited since the first active goal of this tick.
    waited: u32,
    /// Member-ticks the straggler policy filled (tenant registered but
    /// idle while the tick ran), cumulative. A registry [`Counter`] so
    /// `SimServer::stats()` and a scrape read the same cell.
    pub idle_fills: Counter,
    /// Registered/active tenant gauges, mirrored on every mutation (same
    /// discipline as `Coalescer::sync_obs`).
    pub obs_registered: Gauge,
    pub obs_active: Gauge,
}

impl InferenceCoalescer {
    pub fn new(policy: StragglerPolicy) -> InferenceCoalescer {
        InferenceCoalescer {
            policy,
            members: Vec::new(),
            waited: 0,
            idle_fills: Counter::new(),
            obs_registered: Gauge::new(),
            obs_active: Gauge::new(),
        }
    }

    fn sync_obs(&self) {
        self.obs_registered.set(self.registered() as f64);
        self.obs_active.set(self.active() as f64);
    }

    pub fn policy(&self) -> StragglerPolicy {
        self.policy
    }

    /// Register a tenant (starts idle — no goal).
    pub fn register(&mut self, tenant: u64) {
        debug_assert!(self.members.iter().all(|m| m.tenant != tenant));
        self.members.push(Member {
            tenant,
            remaining: 0,
            fresh: false,
        });
        self.sync_obs();
    }

    /// Drop a tenant's registration. Returns whether it was registered.
    /// Mirrors `Coalescer::release`: if the departure drains the last
    /// active goal, the deadline clock resets.
    pub fn unregister(&mut self, tenant: u64) -> bool {
        let before = self.members.len();
        self.members.retain(|m| m.tenant != tenant);
        if !self.has_active() {
            self.waited = 0;
        }
        self.sync_obs();
        self.members.len() != before
    }

    /// Extend `tenant`'s goal by `steps` (saturating at
    /// [`MAX_GOAL_STEPS`]). Returns `false` for an unknown tenant. A goal
    /// posted while idle marks the member fresh (recurrent reset).
    pub fn set_goal(&mut self, tenant: u64, steps: u32) -> bool {
        let Some(m) = self.members.iter_mut().find(|m| m.tenant == tenant) else {
            return false;
        };
        if m.remaining == 0 && steps > 0 {
            m.fresh = true;
        }
        m.remaining = m.remaining.saturating_add(steps).min(MAX_GOAL_STEPS);
        self.sync_obs();
        true
    }

    /// Registered tenants.
    pub fn registered(&self) -> usize {
        self.members.len()
    }

    /// Tenants with an active goal.
    pub fn active(&self) -> usize {
        self.members.iter().filter(|m| m.remaining > 0).count()
    }

    pub fn has_active(&self) -> bool {
        self.members.iter().any(|m| m.remaining > 0)
    }

    /// A full tick can run: at least one tenant, and every registered
    /// tenant has an active goal.
    pub fn ready(&self) -> bool {
        !self.members.is_empty() && self.members.iter().all(|m| m.remaining > 0)
    }

    /// One driver tick elapsed while waiting on idle tenants.
    pub fn tick(&mut self) {
        self.waited += 1;
    }

    pub fn waited(&self) -> u32 {
        self.waited
    }

    /// Commit to running a tick: returns each member's share (active
    /// members' goals are decremented, idle members are counted as
    /// straggler fills) and resets the deadline clock. The driver calls
    /// this exactly once per coalesced forward, under the tenant lock.
    pub fn begin_tick(&mut self) -> Vec<TickShare> {
        self.waited = 0;
        let plan: Vec<TickShare> = self
            .members
            .iter_mut()
            .map(|m| {
                let active = m.remaining > 0;
                let fresh = active && m.fresh;
                if active {
                    m.remaining -= 1;
                    m.fresh = false;
                } else {
                    self.idle_fills.inc();
                }
                TickShare {
                    tenant: m.tenant,
                    active,
                    fresh,
                }
            })
            .collect();
        self.sync_obs();
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::coalescer::FillAction;
    use super::*;

    fn deadline(ticks: u32) -> StragglerPolicy {
        StragglerPolicy::Deadline {
            ticks,
            fill: FillAction::NoOp,
        }
    }

    #[test]
    fn empty_coalescer_is_never_ready() {
        let c = InferenceCoalescer::new(StragglerPolicy::Wait);
        assert!(!c.ready());
        assert!(!c.has_active());
        assert_eq!(c.registered(), 0);
    }

    #[test]
    fn wait_policy_needs_every_member_active() {
        let mut c = InferenceCoalescer::new(StragglerPolicy::Wait);
        c.register(1);
        c.register(2);
        assert!(!c.ready());
        assert!(c.set_goal(1, 4));
        assert!(!c.ready(), "one idle member must hold the tick");
        assert!(c.set_goal(2, 4));
        assert!(c.ready());
    }

    #[test]
    fn goals_accumulate_and_decrement_per_tick() {
        let mut c = InferenceCoalescer::new(StragglerPolicy::Wait);
        c.register(7);
        c.set_goal(7, 2);
        c.set_goal(7, 3); // extends the horizon
        for _ in 0..5 {
            assert!(c.ready());
            let plan = c.begin_tick();
            assert_eq!(plan.len(), 1);
            assert!(plan[0].active);
        }
        assert!(!c.ready());
        assert!(!c.has_active());
    }

    #[test]
    fn first_tick_after_idle_is_fresh() {
        let mut c = InferenceCoalescer::new(StragglerPolicy::Wait);
        c.register(1);
        c.set_goal(1, 2);
        let plan = c.begin_tick();
        assert!(plan[0].fresh, "goal start must reset recurrent rows");
        let plan = c.begin_tick();
        assert!(!plan[0].fresh, "mid-goal ticks keep recurrent state");
        // back to idle, then a new goal: fresh again
        c.set_goal(1, 1);
        let plan = c.begin_tick();
        assert!(plan[0].fresh);
    }

    #[test]
    fn goal_for_unknown_tenant_is_rejected() {
        let mut c = InferenceCoalescer::new(StragglerPolicy::Wait);
        assert!(!c.set_goal(99, 4));
    }

    #[test]
    fn idle_members_are_counted_as_fills() {
        let mut c = InferenceCoalescer::new(deadline(2));
        c.register(1);
        c.register(2);
        c.set_goal(1, 1);
        assert!(!c.ready(), "member 2 idle");
        assert!(c.has_active(), "deadline clock may start");
        c.tick();
        c.tick();
        assert_eq!(c.waited(), 2);
        let plan = c.begin_tick();
        assert_eq!(c.waited(), 0, "begin_tick resets the deadline clock");
        assert!(plan[0].active && !plan[1].active);
        assert_eq!(c.idle_fills.get(), 1);
    }

    #[test]
    fn unregister_drains_and_resets_the_clock() {
        let mut c = InferenceCoalescer::new(deadline(8));
        c.register(1);
        c.register(2);
        c.set_goal(1, 3);
        c.tick();
        assert_eq!(c.waited(), 1);
        assert!(c.unregister(1), "was registered");
        assert!(!c.unregister(1), "idempotent");
        assert_eq!(c.waited(), 0, "no active goal left: clock resets");
        // the remaining idle member alone never fires a tick
        assert!(!c.ready() && !c.has_active());
        c.set_goal(2, 1);
        assert!(c.ready());
    }

    #[test]
    fn goal_steps_saturate_at_the_cap() {
        let mut c = InferenceCoalescer::new(StragglerPolicy::Wait);
        c.register(1);
        c.set_goal(1, u32::MAX);
        c.set_goal(1, u32::MAX);
        let plan = c.begin_tick();
        assert!(plan[0].active);
        // still bounded: the horizon is MAX_GOAL_STEPS, not 2^32
        let mut left = 1u64;
        while c.has_active() {
            c.begin_tick();
            left += 1;
            assert!(left <= MAX_GOAL_STEPS as u64);
        }
        assert_eq!(left, MAX_GOAL_STEPS as u64);
    }
}
