//! In-server policy tenant: batched inference-as-a-service behind the
//! session API.
//!
//! The serve layer up to here amortizes *simulation* across tenants; the
//! act→observe loop still crossed the wire twice per step because every
//! client ran its own policy. This module closes that loop server-side —
//! the paper's batching principle applied one layer up: a session leases
//! env slots *plus* a policy checkpoint
//! ([`SimServer::connect_with_policy`](crate::serve::SimServer::connect_with_policy)),
//! and the server drives `observe → coalesced infer → pick action →
//! submit` itself. Tenant clients only set goals and stream back
//! trajectories:
//!
//! ```ignore
//! let server = SimServer::with_vault(specs, pool, None, Some(vault))?;
//! let mut agent = server.connect_with_policy(Task::PointNav, 4, "test")?;
//! agent.set_goal(64)?;                       // "drive me for 64 steps"
//! while let Some(step) = agent.next_step()? { // obs/action/reward/done
//!     train_or_log(step);
//! }
//! ```
//!
//! ```text
//!  tenant A ──set_goal──┐                       ┌─► TrajStep stream A
//!  tenant B ──set_goal──┤  InferenceCoalescer   ├─► TrajStep stream B
//!  tenant C ──(idle)────┤  (Wait/Deadline tick) │   (C's slots: STOP
//!                       ▼                       │    or repeat fill)
//!              one Exec::run per tick ──────────┘
//!              (full shard width, per variant)
//! ```
//!
//! The pieces mirror the env-serving stack one-for-one: [`PolicyVault`]
//! resolves variants/checkpoints through the same `runtime/` manifest the
//! coordinator's eval uses (and gates on `artifacts/manifest.json` the
//! same way); the [`InferenceCoalescer`](coalescer::InferenceCoalescer)
//! is the tenant-granularity sibling of the per-shard action
//! `Coalescer` (`serve::coalescer`); the driver thread
//! in [`driver`] plays the shard driver's role for inference. Inference
//! always runs at full shard width with the `infer_n{slots}` artifact —
//! tenants are *rows* of the one batched forward, which is what makes a
//! whole-shard tenant bitwise-identical to a client-side
//! `Policy::step_greedy` loop (`rust/tests/tenant.rs`).
//!
//! On the wire, tenants appear as `LEASE_POLICY`/`GOAL`/`TRAJ` frames
//! (DESIGN.md §0.8–0.9), `RemoteClient::open_agent`, and the `bps agent`
//! CLI verb.

pub mod coalescer;
pub(crate) mod driver;
pub mod session;
pub mod vault;

pub use coalescer::{InferenceCoalescer, TickShare, MAX_GOAL_STEPS};
pub use session::{ActionMode, TenantControl, TenantSession, TrajStep};
pub use vault::PolicyVault;
