//! Async multi-client serving layer: sessions multiplexed onto sharded
//! [`EnvBatch`](crate::env::EnvBatch)es.
//!
//! The paper's batch simulator amortizes scene storage, rendering, and
//! synchronization across one large batch of requests (§3, Fig. 2). This
//! module keeps that amortization under **multi-tenancy**: a
//! [`SimServer`] owns N `EnvBatch` shards (heterogeneous tasks allowed,
//! sharing one `WorkerPool`), and many concurrent clients each lease a
//! few env slots instead of owning a simulator:
//!
//! ```ignore
//! let server = SimServer::start(vec![ShardSpec::with_scenes(cfg, scenes)], pool)?;
//! let mut session = server.connect(Task::PointNav, 8)?;   // lease 8 slots
//! loop {
//!     let actions = policy(session.view());
//!     let ticket = session.submit(&actions)?;  // partial batch submission
//!     let view = ticket.wait()?;               // this session's slice of the step
//! }
//! ```
//!
//! Per shard, a [`Coalescer`](coalescer) assembles full batch steps from
//! the sessions' partial submissions: the shard steps when every leased
//! slot has an action, or — under [`StragglerPolicy::Deadline`] — after a
//! deadline tick, with straggler slots filled by a no-op/repeat policy.
//! One `EnvBatch::submit` therefore serves every tenant. Sessions detach
//! and reattach without disturbing co-tenants: freed slots keep stepping
//! on an auto-reset filler action until re-leased.
//!
//! Determinism: with the default `Wait` policy, a single session driving
//! a whole shard produces tensors bitwise-identical to driving the
//! same-seeded `EnvBatch` directly — the coalescer passes its actions
//! through verbatim (`rust/tests/serve.rs`).
//!
//! Observability: every shard's counters live on the [`SimServer`]'s
//! metrics [`Registry`](crate::obs::Registry) — [`SimServer::stats`]
//! and a Prometheus scrape (`bps serve --metrics-addr`, the `STATS`
//! wire frame, `bps stats ADDR`) read the *same cells*, so their
//! numbers can never disagree. [`SimServer::stats`] additionally
//! derives submit→result latency percentiles
//! ([`metrics::Window::percentile`](crate::metrics::Window));
//! [`Session::latency`] reports the same percentiles per client.
//! Per-tick pipeline spans land on the server's
//! [`TraceSink`](crate::obs::TraceSink) when tracing is enabled
//! (`bps serve --trace-out`), and lease lifecycle events on its
//! [`EventLog`](crate::obs::EventLog) (DESIGN.md §0.10).
//!
//! Remote clients: the [`wire`] module puts this whole surface on the
//! network — [`WireServer::listen`] fronts a `SimServer` with a
//! length-prefixed TCP protocol, and [`RemoteClient`] /
//! [`RemoteSession`] mirror `connect`/`Session` with bitwise-identical
//! observation streams (DESIGN.md §0.8).
//!
//! Policy tenancy: the [`tenant`] module moves the *policy* server-side
//! too — [`SimServer::connect_with_policy`] leases env slots plus a
//! checkpoint, an `InferenceCoalescer` batches one `Exec::run` per tick
//! across all tenants of a shard, and clients only set goals and stream
//! trajectories ([`TenantSession`]; `RemoteAgent`/`bps agent` on the
//! wire; DESIGN.md §0.9).

pub mod coalescer;
pub mod fault;
pub mod server;
pub mod session;
pub mod tenant;
pub mod wire;

pub use coalescer::{FillAction, StragglerPolicy};
pub use fault::{FaultSpec, Injector};
pub use server::{
    LeaseDecline, SceneSource, SessionLatency, ShardSpec, ShardStats, SimServer, TenantStats,
    TICK,
};
pub use session::{Session, SessionView, Ticket};
pub use tenant::{ActionMode, PolicyVault, TenantControl, TenantSession, TrajStep};
pub use wire::{
    ConnStats, RemoteAgent, RemoteClient, RemoteSession, RemoteTraj, ResumeCfg, WireConfig,
    WireServer,
};
