//! Grid navmesh: geodesic distance (A* / Dijkstra flood), sampling, motion.
//!
//! Distances are along 8-connected grid paths with unit/√2 step costs
//! (octile metric) scaled by the cell size — a tight upper bound on true
//! geodesic distance that preserves the semantics PointGoalNav needs:
//! reward shaping, SPL, and episode difficulty filtering (paper §4.1).

use crate::geom::vec::{v2, Vec2};
use crate::util::rng::Rng;

const SQRT2: f32 = std::f32::consts::SQRT_2;

/// Walkable-cell navigation grid over the xz plane.
#[derive(Clone, Debug)]
pub struct GridNav {
    pub origin: Vec2,
    pub cell: f32,
    pub w: usize,
    pub h: usize,
    pub walkable: Vec<bool>,
}

/// Dijkstra distance field from a source point: `dist[cell]` is the
/// geodesic distance in meters (f32::INFINITY if unreachable).
#[derive(Clone, Debug)]
pub struct DistField {
    pub dist: Vec<f32>,
    w: usize,
}

impl DistField {
    pub fn at_cell(&self, x: usize, y: usize) -> f32 {
        self.dist[y * self.w + x]
    }
}

impl GridNav {
    pub fn new(origin: Vec2, cell: f32, w: usize, h: usize) -> GridNav {
        GridNav {
            origin,
            cell,
            w,
            h,
            walkable: vec![false; w * h],
        }
    }

    #[inline]
    pub fn idx(&self, x: usize, y: usize) -> usize {
        y * self.w + x
    }

    #[inline]
    pub fn cell_of(&self, p: Vec2) -> Option<(usize, usize)> {
        let fx = (p.x - self.origin.x) / self.cell;
        let fy = (p.y - self.origin.y) / self.cell;
        if fx < 0.0 || fy < 0.0 {
            return None;
        }
        let (x, y) = (fx as usize, fy as usize);
        if x >= self.w || y >= self.h {
            None
        } else {
            Some((x, y))
        }
    }

    #[inline]
    pub fn cell_center(&self, x: usize, y: usize) -> Vec2 {
        v2(
            self.origin.x + (x as f32 + 0.5) * self.cell,
            self.origin.y + (y as f32 + 0.5) * self.cell,
        )
    }

    #[inline]
    pub fn is_walkable(&self, p: Vec2) -> bool {
        match self.cell_of(p) {
            Some((x, y)) => self.walkable[self.idx(x, y)],
            None => false,
        }
    }

    pub fn num_walkable(&self) -> usize {
        self.walkable.iter().filter(|&&b| b).count()
    }

    /// Navigable area in m².
    pub fn area(&self) -> f32 {
        self.num_walkable() as f32 * self.cell * self.cell
    }

    fn neighbors(&self, x: usize, y: usize) -> impl Iterator<Item = (usize, usize, f32)> + '_ {
        const OFFS: [(i32, i32, f32); 8] = [
            (1, 0, 1.0),
            (-1, 0, 1.0),
            (0, 1, 1.0),
            (0, -1, 1.0),
            (1, 1, SQRT2),
            (1, -1, SQRT2),
            (-1, 1, SQRT2),
            (-1, -1, SQRT2),
        ];
        OFFS.iter().filter_map(move |&(dx, dy, c)| {
            let nx = x as i32 + dx;
            let ny = y as i32 + dy;
            if nx < 0 || ny < 0 || nx as usize >= self.w || ny as usize >= self.h {
                return None;
            }
            let (nx, ny) = (nx as usize, ny as usize);
            if !self.walkable[self.idx(nx, ny)] {
                return None;
            }
            // diagonal moves must not cut wall corners
            if dx != 0 && dy != 0 {
                let a = self.idx(nx, y);
                let b = self.idx(x, ny);
                if !self.walkable[a] || !self.walkable[b] {
                    return None;
                }
            }
            Some((nx, ny, c))
        })
    }

    /// Dijkstra flood from `src`: geodesic distance to every cell. This is
    /// the per-episode precomputation — per-step distance queries become
    /// O(1) lookups (the batch simulator's hot path, paper §3.1).
    pub fn dist_field(&self, src: Vec2) -> Option<DistField> {
        let (sx, sy) = self.snap(src)?;
        let mut dist = vec![f32::INFINITY; self.w * self.h];
        let mut heap = std::collections::BinaryHeap::new();
        let start = self.idx(sx, sy);
        dist[start] = 0.0;
        heap.push(HeapEntry {
            cost: 0.0,
            x: sx,
            y: sy,
        });
        while let Some(HeapEntry { cost, x, y }) = heap.pop() {
            if cost > dist[self.idx(x, y)] {
                continue;
            }
            for (nx, ny, step) in self.neighbors(x, y) {
                let nd = cost + step * self.cell;
                let ni = self.idx(nx, ny);
                if nd < dist[ni] {
                    dist[ni] = nd;
                    heap.push(HeapEntry {
                        cost: nd,
                        x: nx,
                        y: ny,
                    });
                }
            }
        }
        Some(DistField { dist, w: self.w })
    }

    /// Geodesic distance between two points via A* (octile heuristic).
    pub fn geodesic(&self, a: Vec2, b: Vec2) -> Option<f32> {
        let (ax, ay) = self.snap(a)?;
        let (bx, by) = self.snap(b)?;
        if (ax, ay) == (bx, by) {
            return Some(0.0);
        }
        let hfn = |x: usize, y: usize| -> f32 {
            let dx = (x as f32 - bx as f32).abs();
            let dy = (y as f32 - by as f32).abs();
            (dx.max(dy) + (SQRT2 - 1.0) * dx.min(dy)) * self.cell
        };
        let mut g = vec![f32::INFINITY; self.w * self.h];
        let mut heap = std::collections::BinaryHeap::new();
        g[self.idx(ax, ay)] = 0.0;
        heap.push(HeapEntry {
            cost: hfn(ax, ay),
            x: ax,
            y: ay,
        });
        while let Some(HeapEntry { cost, x, y }) = heap.pop() {
            if (x, y) == (bx, by) {
                return Some(g[self.idx(x, y)]);
            }
            if cost - hfn(x, y) > g[self.idx(x, y)] + 1e-6 {
                continue;
            }
            for (nx, ny, step) in self.neighbors(x, y) {
                let nd = g[self.idx(x, y)] + step * self.cell;
                let ni = self.idx(nx, ny);
                if nd < g[ni] {
                    g[ni] = nd;
                    heap.push(HeapEntry {
                        cost: nd + hfn(nx, ny),
                        x: nx,
                        y: ny,
                    });
                }
            }
        }
        None
    }

    /// Distance lookup against a precomputed field (snap + read).
    pub fn field_dist(&self, field: &DistField, p: Vec2) -> f32 {
        match self.snap(p) {
            Some((x, y)) => field.at_cell(x, y),
            None => f32::INFINITY,
        }
    }

    /// Snap to the nearest walkable cell (expanding ring search, bounded).
    pub fn snap(&self, p: Vec2) -> Option<(usize, usize)> {
        let (cx, cy) = match self.cell_of(p) {
            Some(c) => c,
            None => {
                // clamp into bounds, then search
                let fx = ((p.x - self.origin.x) / self.cell)
                    .clamp(0.0, self.w as f32 - 1.0) as usize;
                let fy = ((p.y - self.origin.y) / self.cell)
                    .clamp(0.0, self.h as f32 - 1.0) as usize;
                (fx, fy)
            }
        };
        if self.walkable[self.idx(cx, cy)] {
            return Some((cx, cy));
        }
        for ring in 1..=20usize {
            let x0 = cx.saturating_sub(ring);
            let x1 = (cx + ring).min(self.w - 1);
            let y0 = cy.saturating_sub(ring);
            let y1 = (cy + ring).min(self.h - 1);
            let mut best: Option<(usize, usize, f32)> = None;
            for y in y0..=y1 {
                for x in x0..=x1 {
                    if (y != y0 && y != y1 && x != x0 && x != x1)
                        || !self.walkable[self.idx(x, y)]
                    {
                        continue;
                    }
                    let c = self.cell_center(x, y);
                    let d = (c - p).length();
                    if best.map(|(_, _, bd)| d < bd).unwrap_or(true) {
                        best = Some((x, y, d));
                    }
                }
            }
            if let Some((x, y, _)) = best {
                return Some((x, y));
            }
        }
        None
    }

    /// Uniform random walkable position (cell center jittered).
    pub fn random_point(&self, rng: &mut Rng) -> Option<Vec2> {
        let total = self.num_walkable();
        if total == 0 {
            return None;
        }
        for _ in 0..256 {
            let x = rng.range_usize(0, self.w);
            let y = rng.range_usize(0, self.h);
            if self.walkable[self.idx(x, y)] {
                let c = self.cell_center(x, y);
                let j = self.cell * 0.3;
                return Some(v2(
                    c.x + rng.range_f32(-j, j),
                    c.y + rng.range_f32(-j, j),
                ));
            }
        }
        // fall back to a scan (sparse navmeshes)
        let target = rng.range_usize(0, total);
        let mut seen = 0;
        for y in 0..self.h {
            for x in 0..self.w {
                if self.walkable[self.idx(x, y)] {
                    if seen == target {
                        return Some(self.cell_center(x, y));
                    }
                    seen += 1;
                }
            }
        }
        None
    }

    /// Move with wall sliding: try the full step in `delta`; on collision
    /// retain the axis components that stay navigable (Habitat-style
    /// sliding). Sub-steps prevent tunneling through thin walls.
    pub fn move_agent(&self, pos: Vec2, delta: Vec2) -> Vec2 {
        let mut p = pos;
        let len = delta.length();
        if len < 1e-9 {
            return p;
        }
        let steps = (len / (self.cell * 0.5)).ceil().max(1.0) as usize;
        let sub = delta / steps as f32;
        for _ in 0..steps {
            let cand = v2(p.x + sub.x, p.y + sub.y);
            if self.is_walkable(cand) {
                p = cand;
            } else {
                let slide_x = v2(p.x + sub.x, p.y);
                let slide_y = v2(p.x, p.y + sub.y);
                if self.is_walkable(slide_x) {
                    p = slide_x;
                } else if self.is_walkable(slide_y) {
                    p = slide_y;
                } else {
                    break;
                }
            }
        }
        p
    }
}

/// Min-heap entry (BinaryHeap is a max-heap; invert the ordering).
#[derive(PartialEq)]
struct HeapEntry {
    cost: f32,
    x: usize,
    y: usize,
}

impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        o.cost
            .partial_cmp(&self.cost)
            .unwrap_or(std::cmp::Ordering::Equal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// 10x10 m open room with a wall across the middle (door at one end).
    fn room_with_wall() -> GridNav {
        let mut nav = GridNav::new(v2(0.0, 0.0), 0.1, 100, 100);
        for y in 0..100 {
            for x in 0..100 {
                let i = nav.idx(x, y);
                nav.walkable[i] = true;
            }
        }
        // wall at y=50 rows, door at x in [90, 97)
        for x in 0..100 {
            if !(90..97).contains(&x) {
                let i = nav.idx(x, 50);
                nav.walkable[i] = false;
            }
        }
        nav
    }

    #[test]
    fn straight_line_distance() {
        let nav = room_with_wall();
        let d = nav.geodesic(v2(1.0, 1.0), v2(8.0, 1.0)).unwrap();
        assert!((d - 7.0).abs() < 0.2, "{d}");
    }

    #[test]
    fn wall_forces_detour() {
        let nav = room_with_wall();
        let a = v2(1.0, 4.0);
        let b = v2(1.0, 6.0);
        let euclid = (b - a).length();
        let d = nav.geodesic(a, b).unwrap();
        // must route through the door at x~9: roughly 8 + 2 + 8 meters
        assert!(d > 5.0 * euclid, "geodesic {d} vs euclid {euclid}");
    }

    #[test]
    fn dist_field_matches_astar() {
        let nav = room_with_wall();
        let goal = v2(2.0, 8.0);
        let field = nav.dist_field(goal).unwrap();
        for &(px, py) in &[(1.0, 1.0), (9.0, 2.0), (5.0, 7.0), (2.0, 8.0)] {
            let p = v2(px, py);
            let a = nav.geodesic(p, goal).unwrap();
            let f = nav.field_dist(&field, p);
            assert!((a - f).abs() < 1e-3, "at {p:?}: astar {a} field {f}");
        }
    }

    #[test]
    fn unreachable_is_none() {
        let mut nav = room_with_wall();
        // seal the door
        for x in 0..100 {
            let i = nav.idx(x, 50);
            nav.walkable[i] = false;
        }
        assert!(nav.geodesic(v2(1.0, 1.0), v2(1.0, 9.0)).is_none());
        let field = nav.dist_field(v2(1.0, 9.0)).unwrap();
        assert!(nav.field_dist(&field, v2(1.0, 1.0)).is_infinite());
    }

    #[test]
    fn move_agent_slides_along_wall() {
        let nav = room_with_wall();
        // walk straight into the wall: x motion blocked, y motion should slide
        let start = v2(5.0, 4.8);
        let end = nav.move_agent(start, v2(0.3, 0.4));
        assert!(end.x > start.x, "slid in x: {end:?}");
        assert!(nav.is_walkable(end));
        // y stays below the wall
        assert!(end.y < 5.05);
    }

    #[test]
    fn move_agent_never_leaves_navmesh_property() {
        prop::check("move_stays_navigable", 300, |rng| {
            let nav = room_with_wall();
            let mut p = nav.random_point(rng).unwrap();
            assert!(nav.is_walkable(p));
            for _ in 0..20 {
                let ang = rng.range_f32(0.0, std::f32::consts::TAU);
                let d = v2(ang.cos(), ang.sin()) * rng.range_f32(0.0, 0.5);
                p = nav.move_agent(p, d);
                assert!(nav.is_walkable(p), "left navmesh at {p:?}");
            }
        });
    }

    #[test]
    fn geodesic_symmetric_property() {
        prop::check("geodesic_symmetric", 40, |rng| {
            let nav = room_with_wall();
            let a = nav.random_point(rng).unwrap();
            let b = nav.random_point(rng).unwrap();
            let ab = nav.geodesic(a, b).unwrap();
            let ba = nav.geodesic(b, a).unwrap();
            assert!((ab - ba).abs() < 1e-3, "{ab} vs {ba}");
        });
    }

    #[test]
    fn geodesic_triangle_inequality_property() {
        prop::check("geodesic_triangle", 30, |rng| {
            let nav = room_with_wall();
            let a = nav.random_point(rng).unwrap();
            let b = nav.random_point(rng).unwrap();
            let c = nav.random_point(rng).unwrap();
            let ab = nav.geodesic(a, b).unwrap();
            let bc = nav.geodesic(b, c).unwrap();
            let ac = nav.geodesic(a, c).unwrap();
            // tolerance: snapping quantizes endpoints by up to one cell
            assert!(ac <= ab + bc + 4.0 * nav.cell, "{ac} > {ab} + {bc}");
        });
    }

    #[test]
    fn geodesic_lower_bounded_by_euclidean_property() {
        prop::check("geodesic_ge_euclid", 50, |rng| {
            let nav = room_with_wall();
            let a = nav.random_point(rng).unwrap();
            let b = nav.random_point(rng).unwrap();
            let d = nav.geodesic(a, b).unwrap();
            let e = (b - a).length();
            assert!(d >= e - 4.0 * nav.cell, "geodesic {d} < euclid {e}");
        });
    }

    #[test]
    fn snap_finds_nearby_walkable() {
        let nav = room_with_wall();
        // point on the wall row
        let (x, y) = nav.snap(v2(5.0, 5.05)).unwrap();
        assert!(nav.walkable[nav.idx(x, y)]);
        // out of bounds snaps inward
        assert!(nav.snap(v2(-3.0, -3.0)).is_some());
    }

    #[test]
    fn area_counts_cells() {
        let nav = room_with_wall();
        let expect = (100 * 100 - 93) as f32 * 0.01;
        assert!((nav.area() - expect).abs() < 1e-3);
    }
}
