//! Navigation mesh substrate (replaces Habitat-Sim's Recast navmesh —
//! DESIGN.md §1): a walkable-cell grid extracted from the procedural floor
//! plan, A* geodesic distances, Dijkstra distance fields (one flood per
//! episode, O(1) per-step lookups), random navigable point sampling, and
//! wall-sliding agent motion.

pub mod grid;

pub use grid::{DistField, GridNav};
