//! Table A1 reproduction: impact of visual-encoder input resolution on
//! end-to-end FPS (64 vs 128; 128 renders at 256 and downsamples, §4.1).
//!
//! Paper shape: higher resolution costs throughput everywhere; the drop is
//! largest when memory pressure also forces N down.

use bps::bench::{bench_iters, ensure_dataset, measure_fps};
use bps::config::Config;

fn main() {
    let (warmup, iters) = bench_iters(0, 1);
    let dir = ensure_dataset("gibson", 8).expect("dataset");
    println!("# Table A1 — input resolution vs FPS (BPS / BPS-R50)");
    println!("{:<8} {:<10} {:>4} {:>6} {:>10}", "Sensor", "System", "Res", "N", "FPS");
    // (label, variant, res, n, l, mb, scale)
    let rows: Vec<(&str, &str, usize, usize, usize, usize, usize)> = vec![
        ("BPS", "depth64", 64, 64, 32, 2, 1),
        ("BPS", "depth128", 128, 16, 16, 2, 2),
        ("BPS-R50", "r50_depth64", 64, 16, 16, 4, 1),
        ("BPS-R50", "r50_depth128", 128, 16, 16, 4, 2),
        ("BPS", "rgb64", 64, 64, 32, 2, 1),
        ("BPS", "rgb128", 128, 16, 16, 2, 2),
        ("BPS-R50", "r50_rgb128", 128, 16, 16, 4, 2),
    ];
    for (system, variant, res, n, l, mb, scale) in rows {
        if (variant.starts_with("r50") || res == 128) && !bps::bench::bench_full() {
            println!("(heavy row {variant} skipped; set BPS_BENCH_FULL=1)");
            continue;
        }
        if !bps::bench::have_variant(variant) {
            println!("(skipped {variant}: export the preset first)");
            continue;
        }
        let cfg = Config {
            variant: variant.into(),
            num_envs: n,
            rollout_len: l,
            num_minibatches: mb,
            render_scale: scale,
            k_scenes: 4,
            memory_budget_mb: 16 * 1024,
            ..Config::default()
        };
        let sensor = if variant.contains("rgb") { "rgb" } else { "depth" };
        match measure_fps(cfg, &dir, warmup, iters) {
            Ok(r) => println!("{sensor:<8} {system:<10} {res:>4} {n:>6} {:>10.0}", r.fps),
            Err(e) => println!("{sensor:<8} {system:<10} error: {e:#}"),
        }
    }
}
