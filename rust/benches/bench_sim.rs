//! Micro-bench: batch simulator step throughput (steps/sec) vs batch size
//! and thread count — the §3.1 dynamic-scheduling claim in isolation.

use std::sync::Arc;

use bps::bench::dataset;
use bps::sim::{BatchSim, SimConfig, SimOutputs};
use bps::util::pool::WorkerPool;

fn main() {
    let ds = dataset("gibson").expect("dataset");
    let scene = Arc::new(ds.load_scene(&ds.train[0], false).expect("scene"));
    println!("# batch simulator step throughput (PointNav, steps/sec)");
    print!("{:>8}", "N\\thr");
    let threads = [0usize, 2, 4, 8];
    for t in threads {
        print!(" {t:>10}");
    }
    println!();
    for n in [16usize, 64, 256, 1024] {
        print!("{n:>8}");
        for t in threads {
            let pool = WorkerPool::new(t);
            let mut sim = BatchSim::new(
                SimConfig::pointnav(),
                (0..n).map(|_| Arc::clone(&scene)).collect(),
                7,
            );
            let mut out = SimOutputs::with_capacity(n);
            let actions: Vec<u8> = (0..n).map(|i| 1 + (i % 3) as u8).collect();
            // warmup
            for _ in 0..3 {
                sim.step_batch(&pool, &actions, &mut out);
            }
            let reps = 20;
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                sim.step_batch(&pool, &actions, &mut out);
            }
            let sps = (n * reps) as f64 / t0.elapsed().as_secs_f64();
            print!(" {sps:>10.0}");
        }
        println!();
    }
}
