//! Fig. 5 + Table A2 reproduction: runtime breakdown (µs per frame) across
//! Simulation+Rendering / Inference / Learning for every system.
//!
//! Paper shape: for BPS the DNN (inference+learning) dominates (~60%) even
//! with complex 3D rendering — the simulator is no longer the bottleneck;
//! for the R50 systems the DNN share exceeds 90%.

use bps::bench::{bench_iters, ensure_dataset, measure_fps, table1_rows};

fn main() {
    let (warmup, iters) = bench_iters(0, 1);
    let dir = ensure_dataset("gibson", 8).expect("dataset");
    println!("# Table A2 / Fig 5 — runtime breakdown (us per frame)");
    println!(
        "{:<8} {:<10} {:<11} {:>10} {:>10} {:>10} {:>7}",
        "Sensor", "System", "CNN", "Sim+Rend", "Inference", "Learning", "DNN%"
    );
    for sensor in ["depth", "rgb"] {
        for row in table1_rows(sensor, 1) {
            if row.cfg.variant.starts_with("r50") && !bps::bench::bench_full() {
                println!(
                    "{sensor:<8} {:<10} (heavy row skipped; set BPS_BENCH_FULL=1)",
                    row.system
                );
                continue;
            }
            if !bps::bench::have_variant(&row.cfg.variant) {
                println!("(skipped {}: export the preset first)", row.cfg.variant);
                continue;
            }
            match measure_fps(row.cfg.clone(), &dir, warmup, iters) {
                Ok(r) => {
                    let (s, i, l) = r.breakdown;
                    let dnn = (i + l) / (s + i + l).max(1e-9) * 100.0;
                    println!(
                        "{sensor:<8} {:<10} {:<11} {s:>10.1} {i:>10.1} {l:>10.1} {dnn:>6.0}%",
                        row.system, row.cnn
                    );
                    // renderer stage sub-breakdown (reset-on-read per run,
                    // worker-summed — can exceed the wall-clock render row)
                    let (tx, cu, ra, re) = r.render_stages;
                    println!(
                        "{:<31} transform {tx:>7.1}  cull {cu:>7.1}  \
                         raster {ra:>7.1}  resolve {re:>7.1}",
                        ""
                    );
                }
                Err(e) => println!("{sensor:<8} {:<10} error: {e:#}", row.system),
            }
        }
    }
}
