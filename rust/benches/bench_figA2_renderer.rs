//! Fig. A2 reproduction: standalone batch-renderer FPS across resolution x
//! batch size (RGB sensor, Gibson-like scene, camera poses from a rollout).
//!
//! Paper shape: FPS saturates with batch size (by N~512 on the paper's
//! GPU); at small N resolution barely matters (underutilization), at large
//! N higher resolution costs proportionally more.

use std::sync::Arc;

use bps::bench::dataset;
use bps::render::{BatchRenderer, RenderConfig, RenderItem, Sensor};
use bps::util::pool::WorkerPool;
use bps::util::rng::Rng;

fn main() {
    let ds = dataset("gibson").expect("dataset");
    let scene = Arc::new(ds.load_scene(&ds.train[0], true).expect("scene"));
    let pool = WorkerPool::new(WorkerPool::default_size());
    let mut rng = Rng::new(3);
    // camera trace: random navigable poses (a stand-in for a training run)
    let poses: Vec<_> = (0..1024)
        .map(|_| {
            (
                scene.navmesh.random_point(&mut rng).unwrap(),
                rng.range_f32(0.0, std::f32::consts::TAU),
            )
        })
        .collect();
    println!("# Fig A2 — standalone renderer FPS (RGB, {} tris)", scene.mesh.num_tris());
    print!("{:>6}", "N\\res");
    let resolutions = [32usize, 64, 128, 256];
    for r in resolutions {
        print!(" {r:>9}");
    }
    println!();
    for n in [1usize, 8, 32, 128, 512] {
        print!("{n:>6}");
        for res in resolutions {
            let cfg = RenderConfig {
                res,
                sensor: Sensor::Rgb,
                scale: 1,
                mode: bps::render::PipelineMode::Pipelined,
            };
            let renderer = BatchRenderer::new(cfg, n);
            let mut obs = vec![0.0f32; n * cfg.obs_floats()];
            let items: Vec<RenderItem> = (0..n)
                .map(|i| RenderItem {
                    scene: Arc::clone(&scene),
                    pos: poses[i % poses.len()].0,
                    heading: poses[i % poses.len()].1,
                })
                .collect();
            // warmup + measure
            renderer.render_batch(&pool, &items, &mut obs);
            let reps = (256 / n).max(1);
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                renderer.render_batch(&pool, &items, &mut obs);
            }
            let fps = (n * reps) as f64 / t0.elapsed().as_secs_f64();
            print!(" {fps:>9.0}");
        }
        println!();
    }
}
