//! Micro-bench + ablation: renderer pipeline modes and frustum culling —
//! the §3.2 design choices in isolation (DESIGN.md ablation index), with
//! the per-stage breakdown (transform / cull / raster / resolve) from
//! `BatchRenderer::take_stats` and p50/p95 megaframe latency.
//!
//! Knobs: `BPS_BENCH_ITERS=warmup,reps`; `BPS_BENCH_QUICK=1` shrinks to
//! CI-smoke size (test-complexity scenes, N=8). The `bps bench --json`
//! subcommand is the machine-readable face of this bench.

use std::sync::Arc;

use bps::bench::{bench_iters, bench_quick, dataset, measure_render};
use bps::render::{BatchRenderer, PipelineMode, RenderConfig, RenderItem, Sensor};
use bps::util::pool::WorkerPool;
use bps::util::rng::Rng;

fn main() {
    let quick = bench_quick();
    let ds = dataset(if quick { "test" } else { "gibson" }).expect("dataset");
    let scene = Arc::new(ds.load_scene(&ds.train[0], true).expect("scene"));
    let pool = WorkerPool::new(WorkerPool::default_size());
    let mut rng = Rng::new(5);
    let n = if quick { 8 } else { 64 };
    let (warmup, reps) = bench_iters(1, if quick { 3 } else { 10 });
    let items: Vec<RenderItem> = (0..n)
        .map(|_| RenderItem {
            scene: Arc::clone(&scene),
            pos: scene.navmesh.random_point(&mut rng).unwrap(),
            heading: rng.range_f32(0.0, std::f32::consts::TAU),
        })
        .collect();
    println!(
        "# renderer ablations (N={n}, 64px, {} tris/scene, {} workers)",
        scene.mesh.num_tris(),
        pool.num_workers()
    );
    println!(
        "{:<16} {:>9} {:>8} {:>8} {:>6} | {:>9} {:>7} {:>9} {:>8}  us/frame",
        "config", "FPS", "p50 ms", "p95 ms", "cull%", "transform", "cull", "raster", "resolve"
    );
    for (label, mode, sensor) in [
        ("depth fused", PipelineMode::Fused, Sensor::Depth),
        ("depth pipelined", PipelineMode::Pipelined, Sensor::Depth),
        ("rgb   fused", PipelineMode::Fused, Sensor::Rgb),
        ("rgb   pipelined", PipelineMode::Pipelined, Sensor::Rgb),
    ] {
        let cfg = RenderConfig { res: 64, sensor, scale: 1, mode };
        let renderer = BatchRenderer::new(cfg, n);
        let mut obs = vec![0.0f32; n * cfg.obs_floats()];
        let r = measure_render(&renderer, &pool, &items, &mut obs, warmup, reps);
        let [tx, cu, ra, re] = r.stage_us;
        println!(
            "{label:<16} {:>9.0} {:>8.2} {:>8.2} {:>5.1}% | {tx:>9.1} {cu:>7.1} {ra:>9.1} {re:>8.1}",
            r.fps, r.p50_ms, r.p95_ms, r.cull_pct,
        );
    }
}
