//! Micro-bench + ablation: renderer pipeline modes and frustum culling —
//! the §3.2 design choices in isolation (DESIGN.md ablation index).

use std::sync::Arc;

use bps::bench::dataset;
use bps::render::{BatchRenderer, PipelineMode, RenderConfig, RenderItem, Sensor};
use bps::util::pool::WorkerPool;
use bps::util::rng::Rng;

fn main() {
    let ds = dataset("gibson").expect("dataset");
    let scene = Arc::new(ds.load_scene(&ds.train[0], true).expect("scene"));
    let pool = WorkerPool::new(WorkerPool::default_size());
    let mut rng = Rng::new(5);
    let n = 64;
    let items: Vec<RenderItem> = (0..n)
        .map(|_| RenderItem {
            scene: Arc::clone(&scene),
            pos: scene.navmesh.random_point(&mut rng).unwrap(),
            heading: rng.range_f32(0.0, std::f32::consts::TAU),
        })
        .collect();
    println!(
        "# renderer ablations (N={n}, 64px, {} tris/scene)",
        scene.mesh.num_tris()
    );
    for (label, mode, sensor) in [
        ("depth fused", PipelineMode::Fused, Sensor::Depth),
        ("depth pipelined", PipelineMode::Pipelined, Sensor::Depth),
        ("rgb   fused", PipelineMode::Fused, Sensor::Rgb),
        ("rgb   pipelined", PipelineMode::Pipelined, Sensor::Rgb),
    ] {
        let cfg = RenderConfig { res: 64, sensor, scale: 1, mode };
        let renderer = BatchRenderer::new(cfg, n);
        let mut obs = vec![0.0f32; n * cfg.obs_floats()];
        renderer.render_batch(&pool, &items, &mut obs);
        let reps = 10;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            renderer.render_batch(&pool, &items, &mut obs);
        }
        let fps = (n * reps) as f64 / t0.elapsed().as_secs_f64();
        let s = renderer.stats();
        let cullpct = 100.0 * s.chunks_culled as f64 / s.chunks_total.max(1) as f64;
        println!("{label:<16} {fps:>9.0} FPS  ({cullpct:>4.1}% chunks culled)");
    }
}
