//! Serving-layer overhead bench: aggregate FPS at full occupancy through
//! the `bps::serve` multi-tenant layer vs direct `EnvBatch` stepping,
//! swept over client count × envs-per-client, plus per-client step
//! latency percentiles (p50/p95). The coalescer + snapshot-publish cost
//! is bounded when `ratio` stays near 1.0.
//!
//! A third phase runs the same full-occupancy workload through the TCP
//! wire transport (`bps::serve::wire`) over loopback — every client a
//! `RemoteSession` on its own connection — so the serialization +
//! socket cost of going remote is measured against the same direct
//! baseline (`wire_fps` / `w_ratio` / worst-client `w_p95`).
//!
//! A fourth, artifact-gated phase flips the clients into policy
//! tenants (`RemoteAgent`: the server runs inference and drives the
//! envs, clients only stream trajectories) and reports agent-steps/s
//! (`agent_sps`, "-" when no artifact variant matches the geometry).

use std::path::PathBuf;
use std::sync::Arc;

use bps::bench::{bench_iters, dataset};
use bps::env::EnvBatchConfig;
use bps::render::RenderConfig;
use bps::runtime::Manifest;
use bps::scene::SceneAsset;
use bps::serve::{
    PolicyVault, RemoteClient, ShardSpec, SimServer, StragglerPolicy, WireServer,
};
use bps::sim::{Task, NUM_ACTIONS};
use bps::util::pool::WorkerPool;

const RES: usize = 64;

/// Artifact-gated fourth phase: the same full-occupancy workload as
/// policy tenants — `clients` RemoteAgents over loopback, the server
/// running one coalesced forward per tick — reported as agent-steps/s.
/// Returns `None` (printed as "-") without artifacts or when no
/// variant matches the bench geometry (res 64 depth, `infer_n{N}`).
fn agent_sps(
    clients: usize,
    epc: usize,
    steps: usize,
    scene: &Arc<SceneAsset>,
    pool: &Arc<WorkerPool>,
    cfg: EnvBatchConfig,
) -> Option<f64> {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        return None;
    }
    let n = clients * epc;
    let man = Manifest::load(&artifacts).ok()?;
    let variant = man
        .variants
        .values()
        .find(|v| v.res == RES && v.in_ch == 1 && v.infer_ns.contains(&n))?
        .name
        .clone();
    let spec = ShardSpec::with_scenes(cfg, (0..n).map(|_| Arc::clone(scene)).collect())
        .straggler(StragglerPolicy::Wait);
    let vault = PolicyVault::open(&artifacts, None, 1).expect("vault");
    let srv = Arc::new(
        SimServer::with_vault(vec![spec], Arc::clone(pool), None, Some(vault)).expect("server"),
    );
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).expect("listen");
    let addr = wire.local_addr().to_string();
    let agents: Vec<_> = (0..clients)
        .map(|c| {
            let client = RemoteClient::connect(&addr).expect("connect");
            let agent = client
                .open_agent(Task::PointNav, epc, &variant, false, c as u64)
                .expect("open_agent");
            (client, agent)
        })
        .collect();
    // Goals first (a Wait-policy tick needs every tenant active), then
    // time the concurrent drain.
    for (_, agent) in &agents {
        agent.set_goal(steps as u32).expect("set_goal");
    }
    let t0 = std::time::Instant::now();
    std::thread::scope(|sc| {
        for (client, mut agent) in agents {
            sc.spawn(move || {
                for _ in 0..steps {
                    agent
                        .next_traj()
                        .expect("next_traj")
                        .expect("goal ended early");
                }
                agent.detach().expect("detach");
                drop(client);
            });
        }
    });
    Some((n * steps) as f64 / t0.elapsed().as_secs_f64())
}

fn actions_at(t: usize, offset: usize, n: usize) -> Vec<u8> {
    (0..n)
        .map(|i| (1 + (t + offset + i) % (NUM_ACTIONS - 1)) as u8)
        .collect()
}

fn main() {
    let (warmup, iters) = bench_iters(10, 100);
    let ds = dataset("gibson").expect("dataset");
    let scene = Arc::new(ds.load_scene(&ds.train[0], false).expect("scene"));
    let steps = warmup + iters;
    println!(
        "# SimServer coalescing + wire-transport overhead vs direct EnvBatch \
         ({steps} steps, depth {RES})"
    );
    // avg_p50 = mean of per-client p50s; max_p95 = worst client's p95
    println!(
        "{:>8} {:>7} {:>6} {:>11} {:>11} {:>7} {:>10} {:>10} {:>11} {:>8} {:>10} {:>10}",
        "clients",
        "envs/c",
        "N",
        "direct_fps",
        "served_fps",
        "ratio",
        "avg_p50_ms",
        "max_p95_ms",
        "wire_fps",
        "w_ratio",
        "w_p95_ms",
        "agent_sps"
    );
    for clients in [1usize, 2, 4, 8] {
        for epc in [8usize, 32] {
            let n = clients * epc;
            let pool = Arc::new(WorkerPool::new(WorkerPool::default_size()));
            let cfg = EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(RES))
                .seed(2024)
                .overlap(false);

            // Baseline: one caller driving the whole batch directly.
            let mut direct = cfg
                .build_with_scenes(
                    (0..n).map(|_| Arc::clone(&scene)).collect(),
                    Arc::clone(&pool),
                )
                .expect("direct batch");
            let t0 = std::time::Instant::now();
            for t in 0..steps {
                direct.step(&actions_at(t, 0, n)).expect("direct step");
            }
            let direct_fps = (n * steps) as f64 / t0.elapsed().as_secs_f64();
            drop(direct);

            // Served: same batch behind SimServer, `clients` sessions at
            // full occupancy, each driven from its own thread.
            let spec = ShardSpec::with_scenes(cfg, (0..n).map(|_| Arc::clone(&scene)).collect())
                .straggler(StragglerPolicy::Wait);
            let srv = SimServer::start(vec![spec], Arc::clone(&pool)).expect("server");
            let sessions: Vec<_> = (0..clients)
                .map(|_| srv.connect(Task::PointNav, epc).expect("connect"))
                .collect();
            let t0 = std::time::Instant::now();
            let lats: Vec<(f32, f32)> = std::thread::scope(|sc| {
                let handles: Vec<_> = sessions
                    .into_iter()
                    .enumerate()
                    .map(|(c, mut session)| {
                        sc.spawn(move || {
                            for t in 0..steps {
                                session
                                    .step(&actions_at(t, c, epc))
                                    .expect("served step");
                            }
                            session.latency()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let served_fps = (n * steps) as f64 / t0.elapsed().as_secs_f64();
            let p50 = lats.iter().map(|l| l.0).sum::<f32>() / lats.len() as f32;
            let p95 = lats.iter().map(|l| l.1).fold(0.0f32, f32::max);
            drop(srv);

            // Loopback wire: a fresh same-seeded server behind the TCP
            // front-end; every client a RemoteSession on its own socket.
            let spec = ShardSpec::with_scenes(cfg, (0..n).map(|_| Arc::clone(&scene)).collect())
                .straggler(StragglerPolicy::Wait);
            let srv = Arc::new(SimServer::start(vec![spec], Arc::clone(&pool)).expect("server"));
            let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).expect("listen");
            let addr = wire.local_addr().to_string();
            let remotes: Vec<_> = (0..clients)
                .map(|_| {
                    let client = RemoteClient::connect(&addr).expect("connect");
                    let session = client
                        .open_session(Task::PointNav, epc)
                        .expect("open_session");
                    (client, session)
                })
                .collect();
            let t0 = std::time::Instant::now();
            let wire_lats: Vec<(f32, f32)> = std::thread::scope(|sc| {
                let handles: Vec<_> = remotes
                    .into_iter()
                    .enumerate()
                    .map(|(c, (client, mut session))| {
                        sc.spawn(move || {
                            for t in 0..steps {
                                session
                                    .step(&actions_at(t, c, epc))
                                    .expect("wire step");
                            }
                            let lat = session.latency();
                            drop(session);
                            drop(client);
                            lat
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let wire_fps = (n * steps) as f64 / t0.elapsed().as_secs_f64();
            let w_p95 = wire_lats.iter().map(|l| l.1).fold(0.0f32, f32::max);

            // Policy tenancy: server-driven agents over the same wire
            // ("-" without artifacts or a variant exporting infer_n{N}).
            let asps = agent_sps(clients, epc, steps, &scene, &pool, cfg)
                .map_or_else(|| format!("{:>10}", "-"), |s| format!("{s:>10.0}"));
            println!(
                "{clients:>8} {epc:>7} {n:>6} {direct_fps:>11.0} {served_fps:>11.0} \
                 {:>7.3} {:>10.2} {:>10.2} {wire_fps:>11.0} {:>8.3} {:>10.2} {asps}",
                served_fps / direct_fps,
                p50 * 1e3,
                p95 * 1e3,
                wire_fps / direct_fps,
                w_p95 * 1e3
            );
        }
    }
}
