//! Table 1 reproduction: end-to-end training FPS for BPS, BPS-R50,
//! WIJMANS++ and WIJMANS20 on Depth and RGB sensors.
//!
//! Paper shape to check: BPS >> WIJMANS++ > WIJMANS20, with one-to-two
//! orders of magnitude between BPS and WIJMANS20; RGB slower than Depth.
//! Absolute numbers are CPU-testbed-scale (DESIGN.md §1).
//!
//! Usage: cargo bench --bench bench_table1 [-- --shards8]
//! Env: BPS_BENCH_ITERS=warmup,iters (default 1,3)

use bps::bench::{bench_iters, ensure_dataset, measure_fps, table1_rows};

fn main() {
    let shards = if std::env::args().any(|a| a == "--shards8") { 8 } else { 1 };
    let (warmup, iters) = bench_iters(0, 1);
    let dir = ensure_dataset("gibson", 8).expect("dataset");
    println!("# Table 1 — system performance (FPS), CPU testbed, shards={shards}");
    println!(
        "{:<8} {:<10} {:<11} {:>4} {:>6} {:>10} {:>8} {:>8} {:>8}",
        "Sensor", "System", "CNN", "Res", "N", "FPS", "sim+rnd", "infer", "learn"
    );
    for sensor in ["depth", "rgb"] {
        for row in table1_rows(sensor, shards) {
            if row.cfg.variant.starts_with("r50") && !bps::bench::bench_full() {
                println!(
                    "{sensor:<8} {:<10} (heavy row skipped; set BPS_BENCH_FULL=1)",
                    row.system
                );
                continue;
            }
            if !bps::bench::have_variant(&row.cfg.variant) {
                println!(
                    "{sensor:<8} {:<10} (skipped: export preset {} first)",
                    row.system, row.cfg.variant
                );
                continue;
            }
            let n = row.cfg.num_envs;
            match measure_fps(row.cfg.clone(), &dir, warmup, iters) {
                Ok(r) => println!(
                    "{sensor:<8} {:<10} {:<11} {:>4} {n:>6} {:>10.0} {:>8.1} {:>8.1} {:>8.1}",
                    row.system,
                    row.cnn,
                    row.res,
                    r.fps,
                    r.breakdown.0,
                    r.breakdown.1,
                    r.breakdown.2
                ),
                Err(e) => println!("{sensor:<8} {:<10} error: {e:#}", row.system),
            }
        }
    }
}
