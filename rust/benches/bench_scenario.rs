//! Scenario-engine overhead bench: steady-state `EnvBatch` stepping FPS
//! with scenes streamed from the scenario procgen pipeline vs the
//! fixed-dataset rotation path, at matched scene complexity and rotation
//! cadence. The streaming path synthesizes every rotated-in scene from
//! scratch on the shared worker pool — `ratio` near 1.0 means a warm
//! prefetch queue keeps that synthesis off the stepping hot path
//! (`stalls` reports how often it failed to).

use std::sync::Arc;

use bps::bench::bench_iters;
use bps::env::EnvBatchConfig;
use bps::render::{RenderConfig, SceneRotation};
use bps::scenario::{ScenarioSpec, ScenarioStream};
use bps::scene::generate_dataset;
use bps::scene::Complexity;
use bps::sim::{Task, NUM_ACTIONS};
use bps::util::pool::WorkerPool;

const RES: usize = 32;
const K: usize = 2;
const ROTATE_EVERY: u64 = 8;

fn actions_at(t: usize, n: usize) -> Vec<u8> {
    (0..n)
        .map(|i| (1 + (t + i) % (NUM_ACTIONS - 1)) as u8)
        .collect()
}

fn main() {
    let (warmup, iters) = bench_iters(20, 200);
    let steps = warmup + iters;
    // Matched workload: the spec's fixed bands mirror Complexity::test()
    // (6 m extent, light geometry), so both paths step equivalent scenes.
    let spec = ScenarioSpec::parse(
        "name=bench task=pointnav stages=1 tris=600..600 extent=6..6 \
         clutter=1..1 mats=2..2 tex=32",
    )
    .expect("bench spec");

    println!(
        "# scenario streaming vs fixed dataset: {steps} steps, depth {RES}, \
         k={K}, rotate every {ROTATE_EVERY}"
    );
    println!(
        "{:>6} {:>12} {:>12} {:>7} {:>10} {:>7}",
        "N", "dataset_fps", "stream_fps", "ratio", "rotations", "stalls"
    );
    for n in [16usize, 64] {
        // --- baseline: fixed pre-generated dataset, K-slot rotation ----
        let dir = std::env::temp_dir().join("bps_bench_scenario_ds");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ds = generate_dataset(&dir, 6, 0, 0, Complexity::test(), 2024).expect("dataset");
        let pool = Arc::new(WorkerPool::new(WorkerPool::default_size()));
        let rot = SceneRotation::new(ds.clone(), ds.train.clone(), K, false).expect("rotation");
        let mut env = EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(RES))
            .seed(7)
            .overlap(false)
            .pin_rotation(ROTATE_EVERY)
            .build_with_rotation(rot, n, Arc::clone(&pool))
            .expect("dataset batch");
        let t0 = std::time::Instant::now();
        for t in 0..steps {
            env.step(&actions_at(t, n)).expect("dataset step");
            env.rotate_scenes().expect("dataset rotate");
        }
        let dataset_fps = (n * steps) as f64 / t0.elapsed().as_secs_f64();
        drop(env);

        // --- scenario streaming: scenes synthesized ahead of demand ----
        let stream = ScenarioStream::new(spec.clone(), 7, 3, false, Arc::clone(&pool));
        let rot = SceneRotation::streaming(stream, K).expect("streaming rotation");
        let mut env = EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(RES))
            .seed(7)
            .overlap(false)
            .pin_rotation(ROTATE_EVERY)
            .build_with_rotation(rot, n, Arc::clone(&pool))
            .expect("streaming batch");
        let t0 = std::time::Instant::now();
        for t in 0..steps {
            env.step(&actions_at(t, n)).expect("stream step");
            env.rotate_scenes().expect("stream rotate");
        }
        let stream_fps = (n * steps) as f64 / t0.elapsed().as_secs_f64();
        println!(
            "{n:>6} {dataset_fps:>12.0} {stream_fps:>12.0} {:>7.3} {:>10} {:>7}",
            stream_fps / dataset_fps,
            env.rotations(),
            env.feed_stalls()
        );
    }
}
