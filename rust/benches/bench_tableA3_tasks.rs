//! Table A3 reproduction: Flee and Explore tasks on AI2-THOR-like scenes
//! (Depth agents): end-to-end FPS plus the training-score window.
//!
//! Paper shape: both tasks run FASTER than PointGoalNav on the same system
//! because thor-like scenes have far less geometry; Explore > Flee by a
//! small margin (no geodesic distance computation needed per step).

use bps::bench::{bench_iters, ensure_dataset, taskrow_config};
use bps::coordinator::Coordinator;
use bps::sim::Task;

fn main() {
    let (warmup, iters) = bench_iters(0, 1);
    let dir = ensure_dataset("thor", 8).expect("dataset");
    println!("# Table A3 — Flee / Explore (Depth, thor-like scenes)");
    println!("{:<10} {:>10} {:>14}", "Task", "FPS", "TrainScore");
    for task in [Task::PointNav, Task::Flee, Task::Explore] {
        let mut cfg = taskrow_config(task);
        cfg.dataset_dir = dir.clone();
        if !bps::bench::have_variant(&cfg.variant) {
            println!("(skipped: export preset {} first)", cfg.variant);
            continue;
        }
        let mut coord = match Coordinator::new(cfg) {
            Ok(c) => c,
            Err(e) => {
                println!("{task:?}: error: {e:#}");
                continue;
            }
        };
        for _ in 0..warmup {
            coord.train_iteration().unwrap();
        }
        coord.prof.reset();
        let t0 = std::time::Instant::now();
        let mut frames = 0u64;
        for _ in 0..iters {
            frames += coord.train_iteration().unwrap().frames;
        }
        let fps = frames as f64 / t0.elapsed().as_secs_f64();
        println!(
            "{:<10} {fps:>10.0} {:>14.2}",
            format!("{task:?}"),
            coord.stats.score.mean()
        );
    }
}
