//! Interface shim for the `xla` PJRT bindings.
//!
//! Environments with the native XLA runtime installed swap this path crate
//! for the real bindings (same API surface; see DESIGN.md §2). In offline
//! containers — CI included — the shim provides:
//!
//! - a fully functional [`Literal`]: the host-side tensor container the
//!   runtime helpers (`lit_f32`, `to_f32`, …) build and consume, and
//! - PJRT client/executable types whose constructors return a clean
//!   "native runtime unavailable" error instead of failing to link.
//!
//! Every test that needs real execution already gates on the presence of
//! `artifacts/manifest.json` (produced by `make artifacts`, which requires
//! the native runtime anyway), so the shim keeps the whole workspace
//! building and the rest of the test suite running without XLA.

use std::fmt;

/// Errors from the (unavailable) native runtime or literal shape checks.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA native runtime is not available in this build \
             (the `xla` crate is the offline interface shim; install the \
             PJRT bindings and point Cargo at them to enable execution)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// -- literals (functional) --------------------------------------------------

/// Element-type storage (public only because the `NativeType` conversion
/// trait names it; construct literals through [`Literal`]).
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side tensor: flat data + dims. Functional in the shim — literal
/// construction and reshaping are pure host operations.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Sealed-ish conversion trait for supported element types.
pub trait NativeType: Sized + Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal {
            dims: Vec::new(),
            data: T::wrap(vec![x]),
        }
    }

    /// Tuple literal (what executables return).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal {
            dims: Vec::new(),
            data: Data::Tuple(elems),
        }
    }

    fn volume(&self) -> i64 {
        match &self.data {
            Data::F32(v) => v.len() as i64,
            Data::I32(v) => v.len() as i64,
            Data::Tuple(_) => 0,
        }
    }

    /// Same data, new dims; the volume must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.volume() {
            return Err(Error(format!(
                "reshape: volume mismatch ({} elements into shape {dims:?})",
                self.volume()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Copy the flat contents out as `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("to_vec: literal element type mismatch".into()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(elems) => Ok(elems),
            _ => Err(Error("to_tuple: literal is not a tuple".into())),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// -- PJRT surface (stubbed) -------------------------------------------------

/// Parsed HLO module. The shim does not parse; construction fails.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parse HLO text {path:?}")))
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client. `cpu()` fails cleanly in the shim.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments; `[device][output]` buffer grid.
    pub fn execute<T: ExecuteInput>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Types accepted as execution inputs.
pub trait ExecuteInput {}

impl ExecuteInput for Literal {}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(3i32);
        assert_eq!(s.dims(), &[] as &[i64]);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![3]);
        let t = Literal::tuple(vec![s.clone(), Literal::scalar(1.5f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn pjrt_surface_fails_cleanly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("native runtime"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
