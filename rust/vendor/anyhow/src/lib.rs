//! Vendored minimal re-implementation of the `anyhow` API surface used by
//! this workspace. The container/CI environment builds with no registry
//! access, so the crate is a path dependency providing exactly what the
//! codebase needs: `Error`, `Result`, `Context`, `anyhow!`, `bail!`.
//!
//! Differences from upstream anyhow: the error chain is flattened to
//! strings at construction time (no downcasting, no backtraces). Display
//! prints the outermost message; `{:#}` prints the full `a: b: c` chain;
//! Debug prints the multi-line "Caused by:" form, matching what callers
//! (`eprintln!("{e:#}")`, `fn main() -> anyhow::Result<()>`) expect.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the same default type parameter as
/// upstream, so `Result<T>` and `Result<T, E>` both work.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chained error: `chain[0]` is the outermost context message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    fn from_std<E: StdError + ?Sized>(err: &E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, colon-separated.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below and the two `Context` impls
// coherent (same trick as upstream anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_std(&err)
    }
}

/// Attach context to a `Result` or `Option`, producing `anyhow::Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted `Error`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted `Error` when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u8>.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            ensure!(x != 1, "one is banned");
            Ok(x)
        }
        assert!(f(2).is_ok());
        assert_eq!(format!("{:#}", f(-3).unwrap_err()), "negative: -3");
        assert_eq!(format!("{:#}", f(1).unwrap_err()), "one is banned");
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }
}
